//! The threaded simulation core (ISSUE 10): real OS worker threads driving
//! decoupled lanes under the epoch-window protocol of
//! [`shard::WindowGovernor`].
//!
//! # What runs in parallel
//!
//! The platform's futures are deliberately non-`Send` (`Rc`-based state),
//! so a *single* platform instance can never be polled from two threads.
//! What the threaded core parallelizes is a **fleet of independent lanes**:
//! each lane owns a whole simulation (in the figure-9 scale point, one
//! tenant's platform + workload on its own cluster node) built *on* the
//! worker thread from a `Send` job constructor and driven by a resumable
//! [`Stepper`].  Only `Send` data crosses threads: job constructors in,
//! results and counters out, and — for lanes that are coupled (the bench
//! and test harnesses) — wakes through the executors' thread-safe wake
//! queues.
//!
//! # The epoch-window protocol
//!
//! Worker `k` pumps each of its live steppers up to the shared window
//! bound, then reports its earliest pending deadline to the governor and
//! blocks on the embedded [`shard::EpochGate`].  When the whole cohort
//! has arrived, the window advances to the global minimum deadline plus
//! the negotiated *lookahead* ([`crate::netsim::negotiate_lookahead`]) and
//! everyone is released.  Lane virtual clocks therefore never skew by
//! more than one lookahead — the horizon inside which no cross-lane event
//! can affect a lane, so every lane's schedule is bit-identical to
//! pumping it alone (and, by [`Stepper`]'s contract, to a plain
//! `block_on`).  That is the oracle the determinism goldens check: the
//! threaded fleet must reproduce the sequentially-driven fleet exactly.
//!
//! # Worker lifecycle and failure
//!
//! A worker whose roots have all completed **retires** from the gate, so
//! finished lanes never block live ones.  A panic anywhere in a lane
//! (task code, stepper, the worker loop itself) is caught at the thread
//! boundary, **poisons** the gate with the shard id and panic payload,
//! and every surviving worker's next `arrive` aborts with that poison —
//! the run fails fast with
//! [`Error::ShardPanicked`](crate::error::Error::ShardPanicked) instead
//! of deadlocking the barrier.  Global quiescence with unfinished roots
//! (a cross-lane deadlock) takes the same path via the governor's
//! [`Window::Quiesced`](shard::Window::Quiesced) verdict.

use std::future::Future;
use std::panic::AssertUnwindSafe;
use std::pin::Pin;
use std::sync::Arc;
use std::time::Instant;

use super::shard::{self, LaneReport, ShardPanic, Window, WindowGovernor};
use super::{Pump, Stepper};

/// A lane job: a `Send` constructor invoked on the worker thread to build
/// the (non-`Send`) root future it will drive.
pub type LaneJob<T> = Box<dyn FnOnce() -> Pin<Box<dyn Future<Output = T>>> + Send>;

/// Per-worker counters for the scale bench's stall accounting.
#[derive(Debug, Clone, Copy)]
pub struct WorkerStats {
    pub worker: usize,
    /// lanes this worker drove
    pub jobs: usize,
    /// epoch windows this worker participated in
    pub windows: u64,
    /// discrete-event epochs across this worker's lanes
    pub epochs: u64,
    /// wall nanoseconds spent blocked at the epoch gate
    pub stall_ns: u64,
    /// total wall nanoseconds of the worker loop
    pub run_ns: u64,
}

impl WorkerStats {
    /// Barrier-wait share of this worker's wall time, in percent.
    pub fn stall_pct(&self) -> f64 {
        if self.run_ns == 0 {
            0.0
        } else {
            self.stall_ns as f64 / self.run_ns as f64 * 100.0
        }
    }
}

/// A completed fleet run: per-worker results in job order, per-worker
/// counters, and the number of epoch-window rounds the cohort completed.
#[derive(Debug)]
pub struct FleetRun<T> {
    /// `results[w][j]` is the value of worker `w`'s `j`-th job
    pub results: Vec<Vec<T>>,
    pub stats: Vec<WorkerStats>,
    pub windows: u64,
}

/// Drive `jobs[w]` on worker thread `w` under the epoch-window protocol
/// with the given conservative lookahead
/// ([`shard::UNBOUNDED_LOOKAHEAD`] for lanes with no cross-lane edges).
///
/// Returns the per-lane results once every lane completed, or the first
/// [`ShardPanic`] if any worker died or deadlocked.
pub fn run_fleet<T, F>(
    lookahead_ns: u64,
    jobs: Vec<Vec<F>>,
) -> Result<FleetRun<T>, ShardPanic>
where
    T: Send + 'static,
    F: FnOnce() -> Pin<Box<dyn Future<Output = T>>> + Send + 'static,
{
    let workers = jobs.len();
    if workers == 0 {
        return Ok(FleetRun { results: Vec::new(), stats: Vec::new(), windows: 0 });
    }
    let governor = Arc::new(WindowGovernor::new(workers, lookahead_ns));
    let mut handles = Vec::with_capacity(workers);
    for (worker, lane_jobs) in jobs.into_iter().enumerate() {
        let governor = Arc::clone(&governor);
        let handle = std::thread::Builder::new()
            .name(format!("shard-{worker}"))
            .spawn(move || {
                // catch everything below the thread boundary: a panicking
                // lane must poison the gate, not strand the cohort
                let run = std::panic::catch_unwind(AssertUnwindSafe(|| {
                    worker_loop(worker, lane_jobs, &governor)
                }));
                match run {
                    Ok(done) => done, // Ok, or Err carrying a sibling's poison
                    Err(panic) => {
                        let payload = panic_payload(panic.as_ref());
                        governor.poison(worker, payload.clone());
                        Err(ShardPanic { shard: worker, payload })
                    }
                }
            })
            .expect("failed to spawn shard worker thread");
        handles.push(handle);
    }

    let mut results = Vec::with_capacity(workers);
    let mut stats = Vec::with_capacity(workers);
    for (worker, handle) in handles.into_iter().enumerate() {
        match handle.join() {
            Ok(Ok((values, s))) => {
                results.push(values);
                stats.push(s);
            }
            Ok(Err(_)) => {} // resolved below via the gate's first poison
            Err(_) => {
                // the worker died outside catch_unwind (e.g. a poisoned
                // mutex during poison handling) — still fail cleanly
                governor.poison(worker, "worker thread died".to_string());
            }
        }
    }
    if let Some(poison) = governor.poisoned() {
        return Err(poison);
    }
    Ok(FleetRun { results, stats, windows: governor.windows() })
}

/// One worker's drain loop: pump every live stepper to the window bound,
/// report, rendezvous, repeat; retire once all roots completed.
fn worker_loop<T, F>(
    worker: usize,
    jobs: Vec<F>,
    governor: &WindowGovernor,
) -> Result<(Vec<T>, WorkerStats), ShardPanic>
where
    T: 'static,
    F: FnOnce() -> Pin<Box<dyn Future<Output = T>>>,
{
    let started = Instant::now();
    let mut steppers: Vec<Option<Stepper<T>>> = jobs
        .into_iter()
        .map(|build| Some(Stepper::on_lane(worker as u32, build())))
        .collect();
    let mut results: Vec<Option<T>> = steppers.iter().map(|_| None).collect();
    let mut stats = WorkerStats {
        worker,
        jobs: steppers.len(),
        windows: 0,
        epochs: 0,
        stall_ns: 0,
        run_ns: 0,
    };
    let mut window_end = governor.initial_window();
    loop {
        let mut next_deadline: Option<u64> = None;
        let mut progressed = false;
        let mut live = 0usize;
        for (i, slot) in steppers.iter_mut().enumerate() {
            let Some(stepper) = slot else { continue };
            match stepper.pump_until(window_end) {
                Pump::Done => {
                    // completing a root is progress (its last sends may
                    // still be in flight to other lanes)
                    progressed = true;
                    stats.epochs += stepper.epochs();
                    let value = slot
                        .take()
                        .unwrap()
                        .into_result()
                        .expect("finished stepper lost its result");
                    results[i] = Some(value);
                }
                Pump::Idle { next_deadline: d, progressed: p } => {
                    live += 1;
                    progressed |= p;
                    next_deadline = match (next_deadline, d) {
                        (Some(x), Some(y)) => Some(x.min(y)),
                        (x, y) => x.or(y),
                    };
                }
            }
        }
        if live == 0 {
            governor.retire();
            break;
        }
        let stall_started = Instant::now();
        match governor.arrive(LaneReport { next_deadline, progressed })? {
            Window::Open { end_ns } => {
                stats.stall_ns += stall_started.elapsed().as_nanos() as u64;
                stats.windows += 1;
                window_end = end_ns;
            }
            Window::Quiesced => {
                // mirrors the single-thread "executor stalled" panic; the
                // unwind poisons the gate so the cohort aborts with us
                panic!(
                    "executor stalled: shard {worker} holds {live} unfinished \
                     roots, no runnable tasks, no timers on any lane"
                );
            }
        }
    }
    stats.run_ns = started.elapsed().as_nanos() as u64;
    let values = results
        .into_iter()
        .map(|v| v.expect("retired worker with an unfinished lane"))
        .collect();
    Ok((values, stats))
}

fn panic_payload(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{self, channel, Executor, Mode};

    /// The per-lane schedule a job produces: (tag, virtual ns) pairs.
    fn lane_workload(lane: u64) -> Pin<Box<dyn Future<Output = Vec<(u64, u64)>>>> {
        Box::pin(async move {
            let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
            let mut handles = Vec::new();
            for i in 0..12u64 {
                let log = std::rc::Rc::clone(&log);
                handles.push(exec::spawn(async move {
                    exec::sleep_ms(((lane * 5 + i * 7) % 13) as f64).await;
                    log.borrow_mut().push((i, exec::now().0));
                }));
            }
            for h in handles {
                h.await;
            }
            std::rc::Rc::try_unwrap(log).unwrap().into_inner()
        })
    }

    #[test]
    fn fleet_matches_block_on_lane_by_lane() {
        let baseline: Vec<Vec<(u64, u64)>> = (0..6u64)
            .map(|lane| Executor::new(Mode::Virtual).block_on(lane_workload(lane)))
            .collect();
        for lookahead in [1_000_000u64, shard::UNBOUNDED_LOOKAHEAD] {
            // lanes 0..6 over 3 workers, 2 jobs each
            let jobs: Vec<Vec<LaneJob<Vec<(u64, u64)>>>> = (0..3u64)
                .map(|w| {
                    vec![
                        Box::new(move || lane_workload(w)) as LaneJob<_>,
                        Box::new(move || lane_workload(w + 3)) as LaneJob<_>,
                    ]
                })
                .collect();
            let fleet = run_fleet(lookahead, jobs).unwrap();
            assert_eq!(fleet.stats.len(), 3);
            for w in 0..3usize {
                assert_eq!(fleet.results[w][0], baseline[w]);
                assert_eq!(fleet.results[w][1], baseline[w + 3]);
            }
        }
    }

    #[test]
    fn panicking_shard_poisons_the_cohort_instead_of_hanging() {
        // shard 2 of 3 dies mid-run; shards 0 and 1 are still mid-schedule
        // and must be released from the gate with the poison, not hang
        let slow = |lane: u64| {
            move || -> Pin<Box<dyn Future<Output = u64>>> {
                Box::pin(async move {
                    for _ in 0..1_000 {
                        exec::sleep_ms(1.0).await;
                    }
                    lane
                })
            }
        };
        let jobs: Vec<Vec<LaneJob<u64>>> = vec![
            vec![Box::new(slow(0))],
            vec![Box::new(slow(1))],
            vec![Box::new(|| {
                Box::pin(async {
                    exec::sleep_ms(5.0).await;
                    panic!("boom on shard 2");
                })
            })],
        ];
        // finite lookahead: survivors rendezvous every window and observe
        // the poison on their next arrival
        let err = run_fleet(500_000, jobs).unwrap_err();
        assert_eq!(err.shard, 2);
        assert!(err.payload.contains("boom on shard 2"), "payload: {}", err.payload);
    }

    #[test]
    fn coupled_lanes_ping_pong_across_threads() {
        // two lanes exchange messages through Send channel halves; wakes
        // travel through the executors' thread-safe wake queues and the
        // receiving lane's virtual clock is untouched by wall-clock timing
        const ROUNDS: u32 = 10;
        let (to_b, mut from_a) = channel::mpsc::<u32>();
        let (to_a, mut from_b) = channel::mpsc::<u32>();
        let jobs: Vec<Vec<LaneJob<Vec<u64>>>> = vec![
            vec![Box::new(move || {
                Box::pin(async move {
                    let mut stamps = Vec::new();
                    for k in 0..ROUNDS {
                        exec::sleep_ms(2.0).await;
                        to_b.send(k).unwrap();
                        assert_eq!(from_b.recv().await, Some(k));
                        stamps.push(exec::now().0);
                    }
                    stamps
                })
            })],
            vec![Box::new(move || {
                Box::pin(async move {
                    let mut stamps = Vec::new();
                    for k in 0..ROUNDS {
                        assert_eq!(from_a.recv().await, Some(k));
                        exec::sleep_ms(2.0).await;
                        to_a.send(k).unwrap();
                        stamps.push(exec::now().0);
                    }
                    stamps
                })
            })],
        ];
        let fleet = run_fleet(1_000_000, jobs).unwrap();
        // each lane's virtual timestamps are a pure function of its own
        // sleeps: lane A stamps after its k-th 2ms sleep + ack, lane B
        // after its k-th 2ms sleep
        let a: Vec<u64> = (1..=ROUNDS as u64).map(|k| k * 2_000_000).collect();
        assert_eq!(fleet.results[0][0], a);
        assert_eq!(fleet.results[1][0], a);
        assert!(fleet.windows > 0);
    }

    #[test]
    fn global_quiescence_with_a_live_root_fails_as_a_stall() {
        // lane 0 waits forever on a channel whose sender the test holds
        // open; lane 1 finishes instantly and retires.  The governor's
        // confirm round must find the cohort silent and abort the run.
        let (tx, mut rx) = channel::mpsc::<u32>();
        let jobs: Vec<Vec<LaneJob<u32>>> = vec![
            vec![Box::new(move || Box::pin(async move { rx.recv().await.unwrap_or(0) }))],
            vec![Box::new(|| Box::pin(async { 7u32 }))],
        ];
        let err = run_fleet(1_000_000, jobs).unwrap_err();
        assert_eq!(err.shard, 0);
        assert!(err.payload.contains("executor stalled"), "payload: {}", err.payload);
        drop(tx);
    }

    #[test]
    fn workers_without_jobs_retire_without_blocking_the_rest() {
        let jobs: Vec<Vec<LaneJob<u32>>> = vec![
            vec![Box::new(|| {
                Box::pin(async {
                    exec::sleep_ms(25.0).await;
                    41u32
                })
            })],
            vec![],
            vec![],
        ];
        let fleet = run_fleet(1_000_000, jobs).unwrap();
        assert_eq!(fleet.results[0], vec![41]);
        assert!(fleet.results[1].is_empty());
        assert_eq!(fleet.stats[0].jobs, 1);
        assert!(fleet.stats[0].epochs > 0);
    }

    #[test]
    fn fleet_is_deterministic_across_repeated_runs() {
        let run = || {
            let jobs: Vec<Vec<_>> = (0..4u64).map(|w| vec![move || lane_workload(w)]).collect();
            run_fleet(250_000, jobs).unwrap().results
        };
        let first = run();
        for _ in 0..4 {
            assert_eq!(run(), first);
        }
    }
}
