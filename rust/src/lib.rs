//! # Provuse — platform-side function fusion for FaaS (reproduction)
//!
//! Reproduction of *"Provuse: Platform-Side Function Fusion for Performance
//! and Efficiency in FaaS Environments"* (Kowallik et al., CS.DC 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the FaaS platform and the paper's
//!   contribution: API gateway, Function Handler with synchronous-call
//!   detection, the Merger (filesystem union → image build → deploy →
//!   reroute → drain), fusion policy, two platform flavors (tinyFaaS-like
//!   and Kubernetes-like), a simulated container runtime, a network fabric
//!   model, metrics, and a k6-like workload generator.
//! * **Layer 2 (python/compile/model.py)** — the benchmark functions'
//!   compute bodies as JAX graphs, AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels behind those
//!   graphs, validated against a pure-jnp oracle.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO
//! artifacts through the PJRT CPU client (`xla` crate) and executes them
//! from Rust.
//!
//! See `DESIGN.md` for the system inventory and experiment index.

pub mod apps;
pub mod billing;
pub mod cluster;
pub mod config;
pub mod containerd;
pub mod error;
pub mod exec;
pub mod experiments;
pub mod fusion;
pub mod gateway;
pub mod handler;
pub mod httpfront;
pub mod merger;
pub mod metrics;
pub mod netsim;
pub mod platform;
pub mod replica;
pub mod runtime;
pub mod trace;
pub mod util;
pub mod workload;
pub mod xla;

pub use error::{Error, Result};
