//! Network fabric model: calibrated latency sampling for every hop type the
//! platform charges on the request path.
//!
//! This replaces the paper's 2-VM / 10 Gbit/s testbed (DESIGN.md
//! substitution #2).  Each sampler returns a duration in virtual-time
//! milliseconds; the caller charges it with `exec::sleep_ms`.  All sampling
//! is deterministic per seed.

use std::cell::RefCell;

use crate::config::LatencyParams;
use crate::util::rng::Rng;

/// Where a hop's latency sample is drawn from (for per-hop accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Hop {
    /// client -> gateway admission + route lookup
    Gateway,
    /// Kubernetes Service VIP indirection (zero-cost on tiny)
    ServiceIndirection,
    /// one-way instance-to-instance network traversal
    Network,
    /// additional east-west surcharge when a hop crosses node boundaries
    /// (zero for co-located instances and single-node platforms)
    CrossNode,
    /// handler dispatch (entry-point shim)
    Dispatch,
    /// fused same-process call
    Inline,
}

/// Latency fabric: samples per-hop costs from the calibrated distributions.
pub struct Fabric {
    params: LatencyParams,
    rng: RefCell<Rng>,
}

impl Fabric {
    pub fn new(params: LatencyParams, seed: u64) -> Self {
        Fabric { params, rng: RefCell::new(Rng::new(seed ^ 0xFAB1C)) }
    }

    pub fn params(&self) -> &LatencyParams {
        &self.params
    }

    /// Minimum virtual-time cost of a cross-node hop (ms) — the sharded
    /// core's *epoch lookahead*: a `Hop::CrossNode` message sent in epoch
    /// `e` cannot be observed by another shard before `e`'s clock advance,
    /// so worker threads in the threaded milestone may run one epoch
    /// without inter-shard synchronization whenever this floor is
    /// positive.  Single-node calibrations return 0 (no lookahead: every
    /// hop stays on its lane).
    pub fn epoch_lookahead_ms(&self) -> f64 {
        self.params.cross_node_ms.max(0.0)
    }

    /// [`epoch_lookahead_ms`](Fabric::epoch_lookahead_ms) in the virtual
    /// clock's native nanoseconds — the unit the threaded core's
    /// [`WindowGovernor`](crate::exec::shard::WindowGovernor) windows are
    /// denominated in.
    pub fn epoch_lookahead_ns(&self) -> u64 {
        (self.epoch_lookahead_ms() * 1e6) as u64
    }

    /// Sample the latency (ms) of one `hop`.
    pub fn sample(&self, hop: Hop) -> f64 {
        let p = &self.params;
        let mut rng = self.rng.borrow_mut();
        let v = match hop {
            Hop::Gateway => rng.normal_ms(p.gateway_ms, p.gateway_ms * 0.1),
            Hop::ServiceIndirection => {
                if p.service_indirection_ms <= 0.0 {
                    0.0
                } else {
                    rng.normal_ms(p.service_indirection_ms, p.service_indirection_ms * 0.15)
                }
            }
            Hop::Network => rng.lognormal(p.net_hop_ms, p.net_sigma),
            Hop::CrossNode => {
                if p.cross_node_ms <= 0.0 {
                    0.0
                } else {
                    rng.lognormal(p.cross_node_ms, p.cross_node_sigma)
                }
            }
            Hop::Dispatch => rng.normal_ms(p.dispatch_ms, p.dispatch_sigma),
            Hop::Inline => p.inline_call_ms,
        };
        v.max(0.0)
    }

    /// Serialization + deserialization cost (ms) for a payload of
    /// `payload_bytes` (charged once per remote call, sender+receiver).
    pub fn serialize_cost(&self, payload_bytes: usize) -> f64 {
        let p = &self.params;
        p.serialize_base_ms + p.serialize_per_kb_ms * (payload_bytes as f64 / 1024.0)
    }

    /// Total modeled cost (ms) of a remote invocation envelope: gateway +
    /// (service) + network + serialization.  Dispatch is charged separately
    /// by the receiving handler.
    pub fn remote_call_envelope(&self, payload_bytes: usize) -> f64 {
        self.sample(Hop::Gateway)
            + self.sample(Hop::ServiceIndirection)
            + self.sample(Hop::Network)
            + self.serialize_cost(payload_bytes)
    }
}

/// Conservative-PDES lookahead negotiation for a fleet of simulation
/// lanes: the epoch window every worker thread may run without
/// synchronizing is bounded by the *minimum* latency floor over all
/// cross-lane edges.  Each entry in `cross_lane_floors_ns` is the
/// [`Fabric::epoch_lookahead_ns`] floor of one edge that can carry
/// events between lanes owned by different workers.
///
/// An empty slice means no event can ever cross lanes — independent
/// tenants — and the license is unbounded (`None`): workers may pick any
/// window they like (the fig9 fleet driver still paces with a finite
/// batched window so the [`EpochGate`](crate::exec::shard::EpochGate)
/// is exercised and stall accounting stays meaningful).
///
/// A zero floor on any edge collapses the license to zero: the caller
/// must fall back to the single-threaded loop, because a 0-latency
/// cross-lane edge admits no conservative window.
pub fn negotiate_lookahead(cross_lane_floors_ns: &[u64]) -> Option<u64> {
    cross_lane_floors_ns.iter().copied().min()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn fabric(kind_kube: bool) -> Fabric {
        let c = if kind_kube { PlatformConfig::kube() } else { PlatformConfig::tiny() };
        Fabric::new(c.latency, 42)
    }

    #[test]
    fn samples_are_positive_and_finite() {
        let f = fabric(false);
        for hop in [Hop::Gateway, Hop::Network, Hop::Dispatch, Hop::Inline] {
            for _ in 0..1000 {
                let v = f.sample(hop);
                assert!(v.is_finite() && v >= 0.0, "{hop:?}: {v}");
            }
        }
    }

    #[test]
    fn tiny_has_no_service_indirection() {
        let f = fabric(false);
        for _ in 0..100 {
            assert_eq!(f.sample(Hop::ServiceIndirection), 0.0);
        }
        let k = fabric(true);
        let mean: f64 =
            (0..1000).map(|_| k.sample(Hop::ServiceIndirection)).sum::<f64>() / 1000.0;
        assert!((mean - 6.0).abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn network_median_matches_calibration() {
        let f = fabric(false);
        let expected = PlatformConfig::tiny().latency.net_hop_ms;
        let mut v: Vec<f64> = (0..4001).map(|_| f.sample(Hop::Network)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = v[v.len() / 2];
        assert!((med - expected).abs() < 0.15 * expected, "median {med}");
    }

    #[test]
    fn cross_node_surcharge_dwarfs_the_local_hop() {
        let f = fabric(false);
        let local: f64 = (0..500).map(|_| f.sample(Hop::Network)).sum::<f64>() / 500.0;
        let cross: f64 = (0..500).map(|_| f.sample(Hop::CrossNode)).sum::<f64>() / 500.0;
        assert!(cross > 3.0 * local, "cross {cross} vs local {local}");
        // a zeroed surcharge disables cross-node pricing entirely
        let mut p = PlatformConfig::tiny().latency;
        p.cross_node_ms = 0.0;
        let z = Fabric::new(p, 1);
        for _ in 0..100 {
            assert_eq!(z.sample(Hop::CrossNode), 0.0);
        }
    }

    #[test]
    fn inline_is_orders_cheaper_than_remote() {
        let f = fabric(false);
        let inline: f64 = (0..100).map(|_| f.sample(Hop::Inline)).sum::<f64>();
        let remote: f64 = (0..100).map(|_| f.remote_call_envelope(8192)).sum::<f64>();
        assert!(remote > 20.0 * inline, "remote {remote} vs inline {inline}");
    }

    #[test]
    fn serialization_scales_with_size() {
        let f = fabric(false);
        let per_kb = PlatformConfig::tiny().latency.serialize_per_kb_ms;
        let small = f.serialize_cost(1024);
        let big = f.serialize_cost(1024 * 1024);
        assert!(big > small);
        assert!((big - small - per_kb * 1023.0).abs() < 1e-9);
    }

    #[test]
    fn epoch_lookahead_is_the_cross_node_floor() {
        let f = fabric(false);
        assert_eq!(f.epoch_lookahead_ms(), PlatformConfig::tiny().latency.cross_node_ms);
        assert!(f.epoch_lookahead_ms() > 0.0);
        // the lookahead is a *floor*: no cross-node sample undercuts it...
        // within the calibrated distribution's practical support; what the
        // sharded core relies on is only that it is positive when a
        // cross-node surcharge exists and zero when it doesn't
        let mut p = PlatformConfig::tiny().latency;
        p.cross_node_ms = 0.0;
        assert_eq!(Fabric::new(p, 1).epoch_lookahead_ms(), 0.0);
    }

    #[test]
    fn lookahead_negotiation_takes_the_tightest_edge() {
        let f = fabric(false);
        let ns = f.epoch_lookahead_ns();
        assert_eq!(ns, (f.epoch_lookahead_ms() * 1e6) as u64);
        assert!(ns > 0);
        // the fleet license is the minimum over the cross-lane edges
        assert_eq!(negotiate_lookahead(&[ns, ns * 3, ns * 2]), Some(ns));
        // a zero-latency edge collapses the license to zero
        assert_eq!(negotiate_lookahead(&[ns, 0]), Some(0));
        // no cross-lane edges at all: unbounded license
        assert_eq!(negotiate_lookahead(&[]), None);
    }

    #[test]
    fn deterministic_given_seed() {
        let c = PlatformConfig::tiny();
        let a = Fabric::new(c.latency.clone(), 9);
        let b = Fabric::new(c.latency.clone(), 9);
        for _ in 0..100 {
            assert_eq!(a.sample(Hop::Network), b.sample(Hop::Network));
        }
    }
}
