//! The Function Handler (paper §3): per-instance request dispatch, compute
//! execution, outbound-call orchestration, and synchronous-call detection.
//!
//! The paper's handler owns each function's entry point and inspects the
//! blocking state of outbound sockets.  Here the handler *is* the entry
//! point: it executes the function spec, issues its Sync calls concurrently
//! and awaits them (the blocking signal), detaches Async calls, and reports
//! every **remote synchronous** call to the fusion [`Observer`].  Calls
//! whose target resolves to the same instance are inlined — no gateway, no
//! network, no serialization — which is exactly the fused fast path of
//! paper Fig. 1.
//!
//! The per-hop plumbing is keyed by interned [`Sym`]s (ISSUE 5): resolving
//! a route, starting/finishing in-flight accounting, recording the billing
//! event, and reporting to the Observer all pass a `Copy` handle instead
//! of cloning a `String` per hop, so a request's orchestration path does
//! not touch the allocator for names at any depth.

use std::cell::RefCell;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;

use crate::apps::{AppSpec, CallMode};
use crate::billing::{BillingEvent, BillingLedger};
use crate::cluster::{Cluster, NodeId};
use crate::config::PlatformConfig;
use crate::containerd::{Instance, InstanceState};
use crate::error::{Error, Result};
use crate::exec;
use crate::fusion::Observer;
use crate::gateway::Gateway;
use crate::metrics::Recorder;
use crate::netsim::{Fabric, Hop};
use crate::replica::{ReplicaSet, Scaler};
use crate::runtime::ComputeService;
use crate::trace::{SpanKind, TraceCtx, Tracer};
use crate::util::intern::Sym;

/// How child payloads are derived and responses combined (fixed, so vanilla
/// and fused deployments produce byte-identical responses).
const CHILD_MIX: f32 = 0.5;
const COMBINE_WEIGHT: f32 = 0.1;

type LocalBoxFuture<T> = Pin<Box<dyn Future<Output = T>>>;

/// Request dispatcher: the composition of gateway, fabric, handlers and
/// compute that a request traverses.  Cheaply clonable.
#[derive(Clone)]
pub struct Dispatcher {
    inner: Rc<DispatcherInner>,
}

struct DispatcherInner {
    app: AppSpec,
    config: Rc<PlatformConfig>,
    fabric: Fabric,
    gateway: Gateway,
    cluster: Cluster,
    compute: ComputeService,
    observer: Rc<Observer>,
    metrics: Recorder,
    billing: BillingLedger,
    /// request-level span tracer (ISSUE 9); disabled = zero-cost no-op
    tracer: Tracer,
    /// replica supplier for scale-from-zero (set by the platform after
    /// deploy when the autoscaler is armed; None reproduces the seed's
    /// hard NoRoute on an empty set)
    scaler: RefCell<Option<Rc<Scaler>>>,
    payload_len: usize,
    response_len: usize,
}

impl Dispatcher {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        app: AppSpec,
        config: Rc<PlatformConfig>,
        fabric: Fabric,
        gateway: Gateway,
        cluster: Cluster,
        compute: ComputeService,
        observer: Rc<Observer>,
        metrics: Recorder,
        billing: BillingLedger,
        tracer: Tracer,
    ) -> Self {
        let (payload_len, response_len) = match compute.artifacts() {
            Some(set) => (set.batch * set.in_dim, set.batch * set.out_dim),
            None => (2048, 64),
        };
        Dispatcher {
            inner: Rc::new(DispatcherInner {
                app,
                config,
                fabric,
                gateway,
                cluster,
                compute,
                observer,
                metrics,
                billing,
                tracer,
                scaler: RefCell::new(None),
                payload_len,
                response_len,
            }),
        }
    }

    /// Arm scale-from-zero: an arrival on an empty replica set boots a
    /// replica through `scaler` instead of failing.  Called by the
    /// platform after deploy when the autoscaler is configured.
    pub fn set_scaler(&self, scaler: Rc<Scaler>) {
        *self.inner.scaler.borrow_mut() = Some(scaler);
    }

    /// Request payload size expected by entry functions (f32 count).
    pub fn payload_len(&self) -> usize {
        self.inner.payload_len
    }

    pub fn response_len(&self) -> usize {
        self.inner.response_len
    }

    /// Client-facing invocation of `function` through the full remote path.
    /// External clients have no node: the cross-node surcharge never
    /// applies to ingress, so single-node latencies match the seed exactly.
    /// Unknown names are rejected without touching the interner (client
    /// input must not grow the append-only table).
    pub async fn invoke(&self, function: &str, payload: Vec<f32>) -> Result<Vec<f32>> {
        self.invoke_traced(function, payload, None).await
    }

    /// [`Self::invoke`] under a live trace context.  The workload driver
    /// owns the trace lifecycle (`Tracer::begin_request` /
    /// `Tracer::finish_ok` / `Tracer::finish_dropped`) because a timed-out
    /// request's future is dropped mid-flight — only the caller can still
    /// finalize its trace.
    pub async fn invoke_traced(
        &self,
        function: &str,
        payload: Vec<f32>,
        trace: Option<TraceCtx>,
    ) -> Result<Vec<f32>> {
        let Some(sym) = Sym::lookup(function) else {
            return Err(Error::NoRoute(function.to_string()));
        };
        self.invoke_remote(sym, payload, 0, None, trace).await
    }

    /// Full remote invocation: gateway -> (service) -> network -> handler.
    /// `from_node` is the calling instance's node (None for external
    /// clients); a hop whose endpoints live on different nodes pays the
    /// east-west [`Hop::CrossNode`] surcharge each way.
    fn invoke_remote(
        &self,
        function: Sym,
        payload: Vec<f32>,
        depth: u32,
        from_node: Option<NodeId>,
        trace: Option<TraceCtx>,
    ) -> LocalBoxFuture<Result<Vec<f32>>> {
        let this = self.clone();
        Box::pin(async move {
            let d = &this.inner;
            if depth > 64 {
                return Err(Error::Request("call depth exceeded".into()));
            }
            // span frame for this invocation: at depth 0 it is the root
            // request's sole critical child; nested remote calls hang off
            // the caller's exec frame as non-critical children (the
            // caller's Join segment is their critical cover)
            let frame = d.tracer.open_frame(trace, SpanKind::Invoke, function, depth == 0);
            // gateway admission + route lookup. In-flight accounting starts
            // at routing time: once the gateway has committed this request
            // to an instance, a draining original must wait for it
            // ("stopped and deleted as soon as they are no longer
            // processing requests", paper §3).  The slot is attributed to
            // the target function (working-set RAM by in-flight ownership).
            let gateway_ms = d.fabric.sample(Hop::Gateway);
            let set = d.gateway.resolve_set_sym(function)?;
            set.note_arrival(d.metrics.rel_now_ms());
            // load-balance across the set's replicas (singleton sets —
            // the seed shape — return their sole replica without an RNG
            // draw); an empty set means the route scaled to zero and this
            // arrival pays the cold start
            let inst = match set.pick() {
                Some(inst) => inst,
                None => {
                    // scale-from-zero boots and fuse/split/migration
                    // cutover retries stall the request here
                    let stall = d.tracer.start_seg(frame, SpanKind::CutoverStall, function);
                    let inst = this.revive(function, &set).await?;
                    d.tracer.end_seg(stall);
                    inst
                }
            };
            // one interner round-trip per hop, not one per use below
            let name = function.as_str();
            inst.request_started_for(name);
            let crossed = match (from_node, d.cluster.node_of(inst.id())) {
                (Some(from), Some(to)) => from != to,
                _ => false,
            };
            if crossed {
                d.metrics.bump("cross_node_calls");
            }

            // gateway + (kube) service indirection + network (+ cross-node
            // surcharge) + request serialization, charged as one timer
            // (perf: §Perf L3-3).  Components are drawn into locals — same
            // draw order, same sum order, bit-identical env_ms — so a live
            // trace can partition the charged interval exactly.
            let svc_ms = d.fabric.sample(Hop::ServiceIndirection);
            let net_ms = d.fabric.sample(Hop::Network);
            let cross_ms = if crossed { d.fabric.sample(Hop::CrossNode) } else { 0.0 };
            let ser_ms = d.fabric.serialize_cost(payload.len() * 4);
            let env_ms = gateway_ms + svc_ms + net_ms + cross_ms + ser_ms;
            let env_start = exec::now();
            exec::sleep_ms(env_ms).await;
            d.tracer.add_parts(
                frame,
                env_start,
                exec::now(),
                function,
                &[
                    (SpanKind::Gateway, gateway_ms),
                    (SpanKind::ServiceIndirection, svc_ms),
                    (SpanKind::Network, net_ms),
                    (SpanKind::CrossNode, cross_ms),
                    (SpanKind::Serialize, ser_ms),
                ],
            );

            // cold-start wait: a booting instance queues the request
            let cold = d.tracer.start_seg(frame, SpanKind::ColdWait, function);
            while inst.state() == InstanceState::Booting {
                exec::sleep_ms(d.config.latency.health_interval_ms).await;
            }
            d.tracer.end_seg(cold);
            // concurrency gate: a bounded replica queues excess arrivals
            // here (cap 0 = unlimited, the seed behavior — returns
            // immediately without touching the slot counter)
            let gate = d.tracer.start_seg(frame, SpanKind::GateQueue, function);
            let cap = d.config.scaling.concurrency;
            inst.acquire_slot(cap).await;
            d.tracer.end_seg(gate);
            if inst.state() == InstanceState::Terminated {
                inst.release_slot(cap);
                inst.request_finished_for(name);
                return Err(Error::Request(format!(
                    "instance {} terminated before dispatch",
                    inst.id()
                )));
            }

            // handler dispatch (entry-point shim) — remote arrivals only;
            // inlined (fused) calls bypass it entirely (paper Fig. 1).
            // The dispatch charge is folded into the body's compute timer.
            let bill_start = exec::now();
            let dispatch_ms = d.fabric.sample(Hop::Dispatch);
            let result = this
                .execute_function(
                    Rc::clone(&inst),
                    function,
                    payload,
                    depth,
                    dispatch_ms,
                    frame,
                    SpanKind::Dispatch,
                )
                .await;
            inst.release_slot(cap);
            inst.request_finished_for(name);
            // One billed invocation per remote arrival (§2.3): duration x
            // instance allocation, *including* time blocked on sync calls —
            // the double-billing the paper's fusion eliminates.
            d.billing.record(BillingEvent {
                t_ms: d.metrics.rel_now_ms(),
                function,
                duration_ms: exec::now().duration_since(bill_start).as_secs_f64() * 1e3,
                alloc_gb: inst.alloc_mb() / 1024.0,
            });
            let out = result?;

            // response path: serialization + network (+ the cross-node
            // surcharge again) back to the caller
            let ser_back_ms = d.fabric.serialize_cost(out.len() * 4);
            let net_back_ms = d.fabric.sample(Hop::Network);
            let cross_back_ms = if crossed { d.fabric.sample(Hop::CrossNode) } else { 0.0 };
            let back_ms = ser_back_ms + net_back_ms + cross_back_ms;
            let back_start = exec::now();
            exec::sleep_ms(back_ms).await;
            d.tracer.add_parts(
                frame,
                back_start,
                exec::now(),
                function,
                &[
                    (SpanKind::Serialize, ser_back_ms),
                    (SpanKind::Network, net_back_ms),
                    (SpanKind::CrossNode, cross_back_ms),
                ],
            );
            d.tracer.close_frame(frame);
            Ok(out)
        })
    }

    /// Scale-from-zero: the route exists but its set currently has no
    /// routable replica.  The first arrival flips the set's
    /// `scale_pending` guard and boots one replica through the platform's
    /// [`Scaler`] (warm-pool claim when possible); concurrent arrivals
    /// wait for that boot instead of each booting their own — the
    /// thundering herd collapses into one cold start.  Without a scaler
    /// (seed configs never scale to zero) this degrades to the seed's
    /// `NoRoute` error.
    async fn revive(&self, function: Sym, set: &Rc<ReplicaSet>) -> Result<Rc<Instance>> {
        let d = &self.inner;
        let mut set = Rc::clone(set);
        loop {
            if set.is_retired() {
                // a fuse/split cutover replaced this set while we waited;
                // follow the route to its replacement
                set = d.gateway.resolve_set_sym(function)?;
                continue;
            }
            if let Some(inst) = set.pick() {
                return Ok(inst);
            }
            let scaler = d.scaler.borrow().as_ref().map(Rc::clone);
            let Some(scaler) = scaler else {
                return Err(Error::NoRoute(function.as_str().to_string()));
            };
            if set.scale_pending() {
                exec::sleep_ms(d.config.latency.health_interval_ms).await;
                continue;
            }
            set.set_scale_pending(true);
            let booted =
                scaler.add_replica(function.as_str(), &set, "scale-from-zero").await;
            set.set_scale_pending(false);
            match booted {
                // the cutover race: retry against the route's current set
                Err(_) if set.is_retired() => continue,
                other => return other,
            }
        }
    }

    /// Execute `function` on `inst` (already located there): upfront charge
    /// (dispatch for remote arrivals, inline hop for fused calls), compute
    /// body, then the outbound call plan.  `upfront_kind` labels the
    /// upfront charge in a live trace (`Dispatch` or `Inline`) and decides
    /// whether this exec frame is a critical segment of its parent
    /// (remote dispatch) or covered by the caller's Join (inline child).
    #[allow(clippy::too_many_arguments)]
    fn execute_function(
        &self,
        inst: Rc<Instance>,
        function: Sym,
        input: Vec<f32>,
        depth: u32,
        upfront_ms: f64,
        trace: Option<TraceCtx>,
        upfront_kind: SpanKind,
    ) -> LocalBoxFuture<Result<Vec<f32>>> {
        let this = self.clone();
        Box::pin(async move {
            let d = &this.inner;
            let ex = d.tracer.open_frame(
                trace,
                SpanKind::Exec,
                function,
                upfront_kind == SpanKind::Dispatch,
            );
            // borrow, don't clone: the spec is immutable for the platform's
            // lifetime and the clone copied every call edge per invocation
            let spec = d.app.function(function.as_str())?;

            // compute body: real PJRT execution (mode-dependent charging);
            // charged together with the upfront hop as one timer
            let (mut out, compute_ms) = match &spec.body {
                Some(body) => d.compute.run(body, &input)?,
                None => d.compute.run("", &input)?, // orchestration-only fold
            };
            let self_ms = upfront_ms + compute_ms + spec.busy_ms;
            let self_start = exec::now();
            exec::sleep_ms(self_ms).await;
            d.tracer.add_parts(
                ex,
                self_start,
                exec::now(),
                function,
                &[
                    (upfront_kind, upfront_ms),
                    (SpanKind::SelfTime, compute_ms + spec.busy_ms),
                ],
            );
            d.metrics.bump("invocations");
            // per-function handler attribution: the self time (hop + compute
            // + busy, no child waits) gives interior functions of a fused
            // group their own latency series for the defusion cost model
            d.metrics.record_fn_latency(d.metrics.rel_now_ms(), function, self_ms);

            // --- outbound calls ------------------------------------------------
            // Sync calls are issued concurrently and joined in spec order
            // (the handler thread blocks on them -> sync detection); async
            // calls are detached after the sync group completes.
            let mut sync_handles = Vec::new();
            for call in spec.calls.iter().filter(|c| c.mode == CallMode::Sync) {
                let child_payload = this.child_payload(&out, call.scale);
                let target = Sym::intern(&call.target);
                // inline iff the target's replica set contains THIS
                // instance (fused together) — at replica count 1 this is
                // the seed's same-instance id check
                let target_set = d.gateway.resolve_set_sym(target)?;
                let local = target_set.contains(inst.id());
                let fut: LocalBoxFuture<Result<Vec<f32>>> = if local {
                    // fused fast path: in-process call
                    d.metrics.bump("inline_calls");
                    let inline_ms = d.fabric.sample(Hop::Inline);
                    let this2 = this.clone();
                    let inst2 = Rc::clone(&inst);
                    Box::pin(async move {
                        this2
                            .execute_function(
                                inst2,
                                target,
                                child_payload,
                                depth + 1,
                                inline_ms,
                                ex,
                                SpanKind::Inline,
                            )
                            .await
                    })
                } else {
                    // remote sync call: THE fusion signal (paper §3)
                    d.metrics.bump("remote_sync_calls");
                    d.observer.observe_sync_call_sym(function, target);
                    this.invoke_remote(
                        target,
                        child_payload,
                        depth + 1,
                        d.cluster.node_of(inst.id()),
                        ex,
                    )
                };
                // inline work inherits this instance's lane; a remote call
                // runs on the lane of the target's node (its primary
                // replica — the no-Rc-across-shards ownership rule).  Lane
                // choice never alters the schedule (global wake-seq merge),
                // so this is pinning, not reordering.
                sync_handles.push((
                    match this.call_lane(local, &target_set) {
                        Some(lane) => exec::spawn_on(lane, fut),
                        None => exec::spawn(fut),
                    },
                    target,
                ));
            }
            for (handle, target) in sync_handles {
                // the handler blocks here — the sync-detection signal and,
                // in a live trace, the critical Join segment whose interval
                // covers the child's (concurrently recorded) frame
                let join = d.tracer.start_seg(ex, SpanKind::Join, target);
                let joined = handle.await;
                d.tracer.end_seg(join);
                let child_out = joined?;
                combine(&mut out, &child_out);
            }

            // async calls: fire-and-forget (own in-flight accounting so a
            // draining instance is not reclaimed under detached local work)
            for call in spec.calls.iter().filter(|c| c.mode == CallMode::Async) {
                let child_payload = this.child_payload(&out, call.scale);
                let target = Sym::intern(&call.target);
                let target_set = d.gateway.resolve_set_sym(target)?;
                let local = target_set.contains(inst.id());
                let this2 = this.clone();
                d.metrics.bump("async_calls");
                if local {
                    let inline_ms = d.fabric.sample(Hop::Inline);
                    let inst2 = Rc::clone(&inst);
                    // count before detaching so a drain waits for this work
                    inst2.request_started();
                    exec::spawn(async move {
                        // detached work is off the caller's critical path —
                        // async children are never traced
                        let r = this2
                            .execute_function(
                                Rc::clone(&inst2),
                                target,
                                child_payload,
                                depth + 1,
                                inline_ms,
                                None,
                                SpanKind::Inline,
                            )
                            .await;
                        inst2.request_finished();
                        if r.is_err() {
                            this2.inner.metrics.bump("async_failures");
                        }
                    });
                } else {
                    let my_node = d.cluster.node_of(inst.id());
                    // detached remote call: pinned to the target's lane,
                    // same rule as the sync path above
                    let lane = this.call_lane(false, &target_set);
                    let fut = async move {
                        let r = this2
                            .invoke_remote(target, child_payload, depth + 1, my_node, None)
                            .await;
                        if r.is_err() {
                            this2.inner.metrics.bump("async_failures");
                        }
                    };
                    match lane {
                        Some(lane) => {
                            exec::spawn_on(lane, fut);
                        }
                        None => {
                            exec::spawn(fut);
                        }
                    }
                }
            }

            d.tracer.close_frame(ex);
            Ok(out)
        })
    }

    /// Lane an outbound call's task should run on under a sharded
    /// executor: `None` (inherit the caller's lane) for inline calls and
    /// for unsharded runs — keeping the unsharded spawn path untouched —
    /// otherwise the lane of the node hosting the target's primary
    /// replica.  Only a lane *index* leaves this function; the
    /// `Rc<ReplicaSet>` itself never crosses a shard boundary.
    fn call_lane(&self, local: bool, target_set: &ReplicaSet) -> Option<usize> {
        if local {
            return None;
        }
        let shards = exec::shard_count();
        if shards <= 1 {
            return None;
        }
        let primary = target_set.primary()?;
        Some(self.inner.cluster.shard_of(primary.id(), shards))
    }

    /// Derive a child call payload from the caller's output: deterministic
    /// tiling + linear transform (same math in vanilla and fused paths).
    ///
    /// Perf (EXPERIMENTS.md §Perf L3-1): scale the source once, then tile
    /// with `copy_from_slice` chunks — the naive `out[i % len]` loop costs
    /// a div per element and dominated the simulated request's CPU time.
    fn child_payload(&self, out: &[f32], scale: f32) -> Vec<f32> {
        let n = self.inner.payload_len;
        let mut payload = vec![0.0f32; n];
        if out.is_empty() {
            return payload;
        }
        let factor = scale * CHILD_MIX;
        let scaled: Vec<f32> = out.iter().map(|v| v * factor).collect();
        let mut chunks = payload.chunks_exact_mut(scaled.len());
        for chunk in &mut chunks {
            chunk.copy_from_slice(&scaled);
        }
        let rem = chunks.into_remainder();
        rem.copy_from_slice(&scaled[..rem.len()]);
        payload
    }
}

/// Fold a child response into the caller's output (fixed spec order keeps
/// this deterministic and deployment-independent).
fn combine(out: &mut [f32], child: &[f32]) {
    if child.is_empty() {
        return;
    }
    if out.len() == child.len() {
        // common case (uniform body signature): no index arithmetic
        for (v, c) in out.iter_mut().zip(child) {
            *v += COMBINE_WEIGHT * c;
        }
    } else {
        for (i, v) in out.iter_mut().enumerate() {
            *v += COMBINE_WEIGHT * child[i % child.len()];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combine_is_order_dependent_but_deterministic() {
        let mut a = vec![1.0f32; 4];
        combine(&mut a, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a, vec![1.1, 1.2, 1.3, 1.4]);
        let mut b = vec![1.0f32; 4];
        combine(&mut b, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a, b);
    }

    #[test]
    fn combine_handles_len_mismatch() {
        let mut a = vec![0.0f32; 5];
        combine(&mut a, &[1.0, 2.0]);
        assert_eq!(a, vec![0.1, 0.2, 0.1, 0.2, 0.1]);
        combine(&mut a, &[]);
        assert_eq!(a, vec![0.1, 0.2, 0.1, 0.2, 0.1]);
    }
}
