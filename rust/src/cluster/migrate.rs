//! Live migration: move a serving replica set to another node with zero
//! dropped requests, one replica at a time.
//!
//! Pipeline per replica (the Merger cutover contract, re-targeted):
//!
//! 1. resolve the route's replica set through the gateway and verify the
//!    sampled membership still matches the live topology (staleness gate —
//!    a racing fuse/split/evict aborts the migration, never corrupts it);
//! 2. capacity-check the target node (a migration that would breach the
//!    target's RAM capacity is refused up front);
//! 3. launch the same image on the target node and shrink its active set
//!    to match the source replica (an earlier eviction must not resurrect);
//! 4. health-gate the replacement before any traffic moves;
//! 5. re-verify the topology (the boot wait yielded), then swap the
//!    replica inside its set — an in-place cutover no arrival observes;
//! 6. drain the source replica and terminate it once its in-flight
//!    requests finish — a request routed before the swap completes there.
//!
//! Replicas already on the target stay put; moving none at all is
//! reported as a no-op error (the seed's same-node abort, generalized).
//! Failure at any stage rolls back the in-flight replica: the
//! never-routed replacement is torn down and the source keeps serving
//! (replicas moved by earlier iterations stay moved — each cutover is
//! complete on its own).

use std::rc::Rc;

use crate::config::PlatformConfig;
use crate::containerd::Instance;
use crate::error::{Error, Result};
use crate::exec;
use crate::gateway::Gateway;
use crate::metrics::{MigrationEvent, Recorder};
use crate::platform::deployer::Deployer;
use crate::replica::ReplicaSet;

use super::{Cluster, NodeId};

/// Live-migration engine (cheaply clonable).
#[derive(Clone)]
pub struct Migrator {
    cluster: Cluster,
    /// platform-flavored launcher: a Kube migration pays the same
    /// reconcile-tick delay as every other pipeline's replacement launch
    deployer: Deployer,
    gateway: Gateway,
    metrics: Recorder,
    config: Rc<PlatformConfig>,
}

impl Migrator {
    /// A migrator sharing the platform's deployer, gateway, and recorder.
    pub fn new(
        cluster: Cluster,
        deployer: Deployer,
        gateway: Gateway,
        metrics: Recorder,
        config: Rc<PlatformConfig>,
    ) -> Self {
        Migrator { cluster, deployer, gateway, metrics, config }
    }

    /// Move the replica set hosting exactly `functions` (any order) to
    /// node `to`, one replica at a time.  Replicas already on `to` stay
    /// put; moving none is a no-op error.  Returns the last replacement
    /// instance.  `reason` lands in every migration event
    /// ("node_pressure", "fusion_colocation", ...).
    pub async fn migrate(
        &self,
        functions: &[String],
        to: NodeId,
        reason: &'static str,
    ) -> Result<Rc<Instance>> {
        self.metrics.bump("migration_requests");
        let (set, expected) = self.resolve_live(functions)?;

        let mut moved: Option<Rc<Instance>> = None;
        for source in set.live() {
            let from = self.cluster.node_of(source.id()).ok_or_else(|| {
                Error::MigrationAborted(format!(
                    "instance {} has no node assignment",
                    source.id()
                ))
            })?;
            if from == to {
                continue;
            }
            // capacity gate: the replacement lands with the source's
            // current footprint (its in-flight working sets drain on the
            // source, so this slightly over-reserves — erring toward
            // refusal); re-checked per replica against the live ledger
            let target = self.cluster.node(to)?;
            if !target.fits(source.ram_mb()) {
                self.metrics.bump("migration_refused_capacity");
                return Err(Error::MigrationAborted(format!(
                    "migrating [{}] ({:.0} MiB) would breach {to}'s capacity \
                     ({:.0} MiB headroom)",
                    expected.join("+"),
                    source.ram_mb(),
                    target.headroom_mb()
                )));
            }
            let fresh =
                self.migrate_replica(&set, &expected, &source, from, to, reason).await?;
            moved = Some(fresh);
        }

        moved.ok_or_else(|| {
            Error::MigrationAborted(format!(
                "migration of [{}] is a no-op: already on {to}",
                expected.join("+")
            ))
        })
    }

    /// Move one replica of `set` from node `from` to node `to`: launch a
    /// replacement, mirror the active set, health-gate it, then swap it
    /// into the set in place and drain the source.
    async fn migrate_replica(
        &self,
        set: &Rc<ReplicaSet>,
        expected: &[String],
        source: &Rc<Instance>,
        from: NodeId,
        to: NodeId,
        reason: &'static str,
    ) -> Result<Rc<Instance>> {
        let t_start = exec::now();

        // launch the replacement from the source's image on the target
        // (through the platform-flavored deployer) and mirror the source's
        // *active* set (evicted members stay evicted)
        let fresh = self.deployer.launch(source.image(), to).await?;
        for (f, _) in fresh.functions() {
            if !source.hosts(&f) {
                fresh.evict_function(&f)?;
            }
        }

        self.await_healthy(&fresh).await.inspect_err(|_| {
            self.metrics.bump("migration_health_timeouts");
            self.rollback(&fresh);
        })?;

        // the boot wait yielded: re-verify before committing — the set
        // must still own every function and the source must still serve
        for f in expected {
            let routed = match self.gateway.resolve_set(f) {
                Ok(routed) => routed,
                Err(err) => {
                    self.rollback(&fresh);
                    return Err(err);
                }
            };
            if !Rc::ptr_eq(&routed, set) {
                self.rollback(&fresh);
                return Err(Error::MigrationAborted(format!(
                    "topology changed during migration: `{f}` moved off the \
                     replica set of instance {}",
                    source.id()
                )));
            }
        }
        if !set.contains(source.id()) {
            self.rollback(&fresh);
            return Err(Error::MigrationAborted(format!(
                "topology changed during migration: instance {} left its \
                 replica set",
                source.id()
            )));
        }

        // in-place cutover (arrivals pick from the set, so swapping the
        // member is atomic from their view), then drain the source
        set.replace(source.id(), Rc::clone(&fresh));
        self.gateway.bump_version();
        self.metrics.record_migration(MigrationEvent {
            t_ms: self.metrics.rel_now_ms(),
            functions: expected.to_vec(),
            from,
            to,
            duration_ms: exec::now().duration_since(t_start).as_secs_f64() * 1e3,
            reason,
        });
        self.metrics.bump("migrations_completed");
        source.begin_drain()?;
        crate::containerd::reclaim_when_drained(
            self.cluster.control(),
            self.metrics.clone(),
            Rc::clone(source),
        );
        Ok(fresh)
    }

    /// Resolve the replica set hosting exactly `functions` (sorted) —
    /// the same staleness gate as the Merger's defusion pipelines.
    fn resolve_live(&self, functions: &[String]) -> Result<(Rc<ReplicaSet>, Vec<String>)> {
        if functions.is_empty() {
            return Err(Error::MigrationAborted("migration needs at least one function".into()));
        }
        let set = self.gateway.resolve_set(&functions[0])?;
        let source = set.primary().ok_or_else(|| {
            Error::MigrationAborted(format!(
                "stale migration: `{}` has no live replica",
                functions[0]
            ))
        })?;
        let mut hosted: Vec<String> =
            source.functions().iter().map(|(n, _)| n.clone()).collect();
        hosted.sort();
        let mut expected: Vec<String> = functions.to_vec();
        expected.sort();
        if hosted != expected {
            return Err(Error::MigrationAborted(format!(
                "stale migration: sampled [{}] but instance {} hosts [{}]",
                expected.join("+"),
                source.id(),
                hosted.join("+")
            )));
        }
        for f in &expected {
            if !Rc::ptr_eq(&self.gateway.resolve_set(f)?, &set) {
                return Err(Error::MigrationAborted(format!(
                    "stale migration: `{f}` no longer routed with `{}`",
                    expected[0]
                )));
            }
        }
        Ok((set, expected))
    }

    /// The shared pre-cutover health gate (see
    /// [`crate::containerd::await_healthy`]).
    async fn await_healthy(&self, inst: &Rc<Instance>) -> Result<()> {
        crate::containerd::await_healthy(&self.config.latency, inst).await
    }

    /// Tear down a never-routed replacement.
    fn rollback(&self, fresh: &Rc<Instance>) {
        let _ = fresh.begin_drain();
        let _ = self.cluster.control().terminate(fresh);
        self.metrics.bump("migrations_rolled_back");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::containerd::{FsManifest, InstanceState};
    use crate::exec::run_virtual;

    fn setup(nodes: usize, capacity: f64) -> (Migrator, Rc<Instance>) {
        let mut cfg = PlatformConfig::tiny();
        cfg.cluster.nodes = nodes;
        cfg.cluster.node_capacity_mb = capacity;
        cfg.latency.boot_ms = 150.0;
        let cfg = Rc::new(cfg);
        let cluster = Cluster::new(&cfg);
        let gateway = Gateway::new();
        let metrics = Recorder::new();
        let img = cluster
            .control()
            .register_image(FsManifest::function_code("a", 16), vec![("a".into(), 9.0)]);
        let inst = cluster.launch_on(NodeId(0), img).unwrap();
        gateway.set_route("a", Rc::clone(&inst));
        let deployer = Deployer::direct(cluster.clone());
        (Migrator::new(cluster, deployer, gateway, metrics, cfg), inst)
    }

    #[test]
    fn migration_moves_route_and_drains_source() {
        run_virtual(async {
            let (m, source) = setup(2, 0.0);
            crate::exec::sleep_ms(1_000.0).await;
            source.request_started_for("a"); // in-flight across the cutover
            let fresh =
                m.migrate(&["a".to_string()], NodeId(1), "test").await.unwrap();
            assert_eq!(m.cluster.node_of(fresh.id()), Some(NodeId(1)));
            assert_eq!(m.gateway.resolve("a").unwrap().id(), fresh.id());
            // the source drains, then terminates; the in-flight request
            // holds it in Draining until it finishes
            assert_eq!(source.state(), InstanceState::Draining);
            source.request_finished_for("a");
            crate::exec::sleep_ms(500.0).await;
            assert_eq!(source.state(), InstanceState::Terminated);
            assert_eq!(m.metrics.migrations().len(), 1);
            assert_eq!(m.metrics.migrations()[0].from, NodeId(0));
            assert_eq!(m.metrics.migrations()[0].to, NodeId(1));
        });
    }

    #[test]
    fn migration_to_same_node_and_unknown_group_abort() {
        run_virtual(async {
            let (m, _source) = setup(2, 0.0);
            crate::exec::sleep_ms(1_000.0).await;
            assert!(m.migrate(&["a".to_string()], NodeId(0), "test").await.is_err());
            assert!(m.migrate(&["ghost".to_string()], NodeId(1), "test").await.is_err());
            assert!(m.metrics.migrations().is_empty());
        });
    }

    #[test]
    fn migration_refused_when_target_capacity_would_breach() {
        run_virtual(async {
            let (m, source) = setup(2, 60.0); // instance is 67 MiB > 60
            crate::exec::sleep_ms(1_000.0).await;
            let err = m.migrate(&["a".to_string()], NodeId(1), "test").await.unwrap_err();
            assert!(err.to_string().contains("capacity"), "{err}");
            // the source never stopped serving
            assert_eq!(source.state(), InstanceState::Healthy);
            assert_eq!(m.gateway.resolve("a").unwrap().id(), source.id());
        });
    }

    #[test]
    fn every_replica_of_a_set_moves_one_at_a_time() {
        run_virtual(async {
            let (m, founder) = setup(2, 0.0);
            // grow the route to two replicas, both on node 0
            let set = m.gateway.resolve_set("a").unwrap();
            let extra = m.cluster.launch_on(NodeId(0), founder.image()).unwrap();
            set.add(Rc::clone(&extra));
            crate::exec::sleep_ms(1_000.0).await;

            let fresh =
                m.migrate(&["a".to_string()], NodeId(1), "test").await.unwrap();
            assert_eq!(m.cluster.node_of(fresh.id()), Some(NodeId(1)));
            // both replicas were replaced on the target node...
            let moved = m.gateway.resolve_set("a").unwrap();
            assert_eq!(moved.live_len(), 2);
            for inst in moved.live() {
                assert_eq!(m.cluster.node_of(inst.id()), Some(NodeId(1)));
            }
            // ...and both sources drained away (no in-flight requests)
            crate::exec::sleep_ms(500.0).await;
            assert_eq!(founder.state(), InstanceState::Terminated);
            assert_eq!(extra.state(), InstanceState::Terminated);
            assert_eq!(m.metrics.migrations().len(), 2);
            assert_eq!(m.metrics.counter("migrations_completed"), 2);
        });
    }

    #[test]
    fn boot_hang_rolls_back_without_touching_the_source() {
        run_virtual(async {
            let (m, source) = setup(2, 0.0);
            crate::exec::sleep_ms(1_000.0).await;
            m.cluster.node(NodeId(1)).unwrap().containers().inject_boot_hangs(1);
            let err = m.migrate(&["a".to_string()], NodeId(1), "test").await;
            assert!(err.is_err());
            assert_eq!(source.state(), InstanceState::Healthy);
            assert_eq!(m.gateway.resolve("a").unwrap().id(), source.id());
            assert_eq!(m.metrics.counter("migrations_rolled_back"), 1);
            // the hung replacement was reclaimed: only the source lives
            assert_eq!(m.cluster.live_count(), 1);
        });
    }
}
