//! Multi-node cluster substrate: per-node container runtimes behind one
//! image registry, placement-aware scheduling, and live instance
//! migration.
//!
//! The paper's second implementation targets Kubernetes precisely because
//! provider-managed FaaS runs on a fleet of nodes — and fusion interacts
//! with placement: an inline (fused) call is only possible when caller and
//! callee share a process, which first requires sharing a **node**.  This
//! module adds that missing dimension:
//!
//! * [`Node`] — one machine: its own [`ContainerRuntime`] (instances,
//!   lifecycle, fault injection) with a RAM capacity, sharing the
//!   cluster-wide [`crate::containerd::ImageStore`] so any node can pull
//!   any image.
//! * [`Cluster`] — the fleet: node lookup, instance→node assignment,
//!   aggregate RAM/instance accounting (the single-node seed platform is a
//!   one-node cluster, bit-for-bit).
//! * [`Scheduler`] — pluggable placement ([`crate::config::PlacementPolicy`]): bin-pack,
//!   spread, or fusion-affinity (co-locate statically predicted sync
//!   fusion groups so fusing them never needs a migration).
//! * [`Migrator`] — moves a live instance between nodes with the same
//!   safety contract as the Merger pipelines: deploy on target → health
//!   gate → atomic route cutover → drain source, rollback on any failure,
//!   zero dropped requests.

mod migrate;
mod scheduler;

pub use migrate::Migrator;
pub use scheduler::Scheduler;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::config::PlatformConfig;
use crate::containerd::{ContainerRuntime, ImageId, Instance, InstanceId};
use crate::error::{Error, Result};

/// Unique node identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u64);

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "node-{}", self.0)
    }
}

/// One cluster node: a container runtime with a RAM capacity.
pub struct Node {
    id: NodeId,
    /// RAM capacity (MiB); 0 = uncapped
    capacity_mb: f64,
    containers: ContainerRuntime,
}

impl Node {
    /// The node's id.
    pub fn id(&self) -> NodeId {
        self.id
    }

    /// RAM capacity (MiB); 0 = uncapped.
    pub fn capacity_mb(&self) -> f64 {
        self.capacity_mb
    }

    /// This node's container runtime (instances + fault injection).
    pub fn containers(&self) -> &ContainerRuntime {
        &self.containers
    }

    /// RAM in use across this node's live instances (MiB).
    pub fn ram_mb(&self) -> f64 {
        self.containers.total_ram_mb()
    }

    /// Live (booting/healthy/draining) instances on this node.
    pub fn live_count(&self) -> usize {
        self.containers.live_count()
    }

    /// Remaining capacity (MiB); infinite when uncapped.
    pub fn headroom_mb(&self) -> f64 {
        if self.capacity_mb <= 0.0 {
            f64::INFINITY
        } else {
            self.capacity_mb - self.ram_mb()
        }
    }

    /// Whether an additional `ram_mb` MiB would still fit under capacity.
    pub fn fits(&self, ram_mb: f64) -> bool {
        self.headroom_mb() >= ram_mb
    }
}

/// Handle to the node fleet (cheaply clonable).
#[derive(Clone)]
pub struct Cluster {
    inner: Rc<ClusterInner>,
}

struct ClusterInner {
    nodes: Vec<Rc<Node>>,
    /// instance → node (entries persist past termination; lookups are only
    /// ever made for live instances)
    assignments: RefCell<HashMap<InstanceId, NodeId>>,
}

impl Cluster {
    /// Build the fleet described by `config.cluster`: `nodes.max(1)` nodes,
    /// each with its own instance registry, all sharing one image store.
    pub fn new(config: &Rc<PlatformConfig>) -> Cluster {
        let n = config.cluster.nodes.max(1);
        let capacity = config.cluster.node_capacity_mb;
        let mut nodes = Vec::with_capacity(n);
        let first = ContainerRuntime::new(Rc::clone(config));
        let store = first.image_store();
        nodes.push(Rc::new(Node { id: NodeId(0), capacity_mb: capacity, containers: first }));
        for i in 1..n {
            nodes.push(Rc::new(Node {
                id: NodeId(i as u64),
                capacity_mb: capacity,
                containers: ContainerRuntime::with_images(Rc::clone(config), Rc::clone(&store)),
            }));
        }
        Cluster {
            inner: Rc::new(ClusterInner { nodes, assignments: RefCell::new(HashMap::new()) }),
        }
    }

    /// Number of nodes in the cluster.
    pub fn node_count(&self) -> usize {
        self.inner.nodes.len()
    }

    /// All nodes, in id order.
    pub fn nodes(&self) -> Vec<Rc<Node>> {
        self.inner.nodes.clone()
    }

    /// Look up a node by id.
    pub fn node(&self, id: NodeId) -> Result<Rc<Node>> {
        self.inner
            .nodes
            .get(id.0 as usize)
            .cloned()
            .ok_or_else(|| Error::Config(format!("unknown node `{id}`")))
    }

    /// The control-plane runtime handle (node 0).  Image registration and
    /// builds go through the shared store, so any node's handle serves;
    /// this one is also what a single-node platform exposes as *the*
    /// runtime.
    pub fn control(&self) -> ContainerRuntime {
        self.inner.nodes[0].containers.clone()
    }

    /// Launch an instance of `image` on `node` and record the assignment.
    pub fn launch_on(&self, node: NodeId, image: ImageId) -> Result<Rc<Instance>> {
        let n = self.node(node)?;
        let inst = n.containers.launch(image)?;
        self.inner.assignments.borrow_mut().insert(inst.id(), node);
        Ok(inst)
    }

    /// Which node hosts `instance` (None for unknown/foreign instances).
    pub fn node_of(&self, instance: InstanceId) -> Option<NodeId> {
        self.inner.assignments.borrow().get(&instance).copied()
    }

    /// Simulation-core lane hosting `instance`'s work under a sharded
    /// executor: node `n` owns lane `n % shards` (with `--shards` =
    /// `--nodes` each node gets its own lane).  Unknown instances fall to
    /// the control lane 0.  The mapping is pure arithmetic so 1-shard and
    /// N-shard runs agree on ownership — a precondition for the fig9
    /// transcript-parity check.
    pub fn shard_of(&self, instance: InstanceId, shards: usize) -> usize {
        match self.node_of(instance) {
            Some(node) => node.0 as usize % shards.max(1),
            None => 0,
        }
    }

    /// Total RAM across every node's live instances (MiB).
    pub fn total_ram_mb(&self) -> f64 {
        self.inner.nodes.iter().map(|n| n.ram_mb()).sum()
    }

    /// Live instances across the whole fleet.
    pub fn live_count(&self) -> usize {
        self.inner.nodes.iter().map(|n| n.live_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::containerd::FsManifest;
    use crate::exec::{self, run_virtual};

    fn cluster_of(n: usize, capacity: f64) -> (Cluster, ImageId) {
        let mut cfg = PlatformConfig::tiny();
        cfg.cluster.nodes = n;
        cfg.cluster.node_capacity_mb = capacity;
        let cluster = Cluster::new(&Rc::new(cfg));
        let img = cluster
            .control()
            .register_image(FsManifest::function_code("a", 16), vec![("a".into(), 9.0)]);
        (cluster, img)
    }

    #[test]
    fn fleet_shape_and_aggregates() {
        run_virtual(async {
            let (cluster, img) = cluster_of(3, 0.0);
            assert_eq!(cluster.node_count(), 3);
            let i0 = cluster.launch_on(NodeId(0), img).unwrap();
            let i2 = cluster.launch_on(NodeId(2), img).unwrap();
            exec::sleep_ms(2_000.0).await;
            assert_eq!(cluster.node_of(i0.id()), Some(NodeId(0)));
            assert_eq!(cluster.node_of(i2.id()), Some(NodeId(2)));
            assert_eq!(cluster.live_count(), 2);
            // aggregate == sum of per-node ledgers (2 x (58 base + 9 code))
            let per_node: f64 = cluster.nodes().iter().map(|n| n.ram_mb()).sum();
            assert!((cluster.total_ram_mb() - per_node).abs() < 1e-9);
            assert!((per_node - 2.0 * 67.0).abs() < 1e-9);
            assert!(cluster.node(NodeId(7)).is_err());
        });
    }

    #[test]
    fn headroom_and_fits_respect_capacity() {
        run_virtual(async {
            let (cluster, img) = cluster_of(2, 100.0);
            let node = cluster.node(NodeId(0)).unwrap();
            assert_eq!(node.headroom_mb(), 100.0);
            assert!(node.fits(67.0));
            let _i = cluster.launch_on(NodeId(0), img).unwrap();
            exec::sleep_ms(2_000.0).await;
            assert!((node.headroom_mb() - 33.0).abs() < 1e-9);
            assert!(!node.fits(67.0));
            // uncapped nodes have infinite headroom
            let (uncapped, _) = cluster_of(1, 0.0);
            assert!(uncapped.node(NodeId(0)).unwrap().headroom_mb().is_infinite());
        });
    }

    #[test]
    fn single_node_cluster_wraps_the_seed_runtime() {
        let (cluster, img) = cluster_of(1, 0.0);
        assert_eq!(cluster.node_count(), 1);
        // the control handle IS node 0's runtime: images registered through
        // either are visible to both
        assert!(cluster.control().image(img).is_ok());
        assert!(cluster.node(NodeId(0)).unwrap().containers().image(img).is_ok());
    }
}
