//! Placement-aware scheduling: which node a fresh instance lands on.
//!
//! Three policies ([`crate::config::PlacementPolicy`]):
//!
//! * **bin-pack** — fill the most-loaded node that still fits.  Minimizes
//!   nodes in use (a consolidation-first provider), at the price of
//!   hot-spotting.
//! * **spread** — always pick the node with the most headroom.  The
//!   classic availability default — and the negative control for fusion:
//!   it maximizes cross-node sync hops.
//! * **fusion-affinity** — the policy the fusion planner wants: the app's
//!   statically predicted sync fusion groups ([`AppSpec::sync_fusion_groups`])
//!   are placed as *units* (spread across nodes like `spread`, but members
//!   always together), so the Merger never has to migrate to co-locate.
//!   A group too big for any node degrades gracefully to per-function
//!   spread.

use std::collections::BTreeMap;

use crate::apps::AppSpec;
use crate::config::{PlacementPolicy, RamParams};
use crate::error::{Error, Result};

use super::{Cluster, NodeId};

/// Placement engine over a [`Cluster`] (cheaply clonable).
#[derive(Clone)]
pub struct Scheduler {
    policy: PlacementPolicy,
    cluster: Cluster,
}

impl Scheduler {
    /// A scheduler over `cluster` using `policy` for every placement.
    pub fn new(policy: PlacementPolicy, cluster: Cluster) -> Self {
        Scheduler { policy, cluster }
    }

    /// The placement policy this scheduler was built with.
    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Choose a node for one fresh instance needing `ram_mb` MiB, against
    /// the *live* per-node load (the same `pick` kernel the
    /// deployment planner uses, fed live ledgers instead of planned ones;
    /// fusion-affinity places singletons like `Spread` — the affinity
    /// special-casing is in [`Scheduler::place_app`]).  Errors when no
    /// node has the headroom (the caller surfaces it as an aborted
    /// pipeline, never a drop).
    pub fn place(&self, ram_mb: f64) -> Result<NodeId> {
        let nodes = self.cluster.nodes();
        let capacities: Vec<f64> = nodes.iter().map(|n| n.capacity_mb()).collect();
        let loads: Vec<f64> = nodes.iter().map(|n| n.ram_mb()).collect();
        Self::pick(self.policy, &capacities, &loads, ram_mb)
            .map(|i| NodeId(i as u64))
            .ok_or_else(|| {
                Error::Config(format!(
                    "no node can fit {ram_mb:.0} MiB under the {} policy",
                    self.policy.name()
                ))
            })
    }

    /// Plan the initial deployment of an entire app: function → node.
    /// Runs against *planned* (not live) load, since nothing is launched
    /// yet.  Errors when any function fits on no node.
    pub fn place_app(&self, app: &AppSpec, ram: &RamParams) -> Result<BTreeMap<String, NodeId>> {
        let nodes = self.cluster.nodes();
        let capacities: Vec<f64> = nodes.iter().map(|n| n.capacity_mb()).collect();
        let mut planned = vec![0.0f64; nodes.len()];
        let mut plan = BTreeMap::new();

        // placement units: sync fusion groups under fusion-affinity (each
        // group one unit), singleton functions otherwise
        let units: Vec<Vec<String>> = match self.policy {
            PlacementPolicy::FusionAffinity => app.sync_fusion_groups(),
            _ => app.functions().map(|f| vec![f.name.clone()]).collect(),
        };

        for unit in units {
            let unit_mb: f64 = unit
                .iter()
                .map(|f| Self::estimate_mb(app, ram, f))
                .sum();
            match Self::pick(self.policy, &capacities, &planned, unit_mb) {
                Some(node) => {
                    planned[node] += unit_mb;
                    for f in unit {
                        plan.insert(f, NodeId(node as u64));
                    }
                }
                None if unit.len() > 1 => {
                    // the whole group fits nowhere: degrade to per-function
                    // spread rather than refusing to deploy
                    for f in unit {
                        let mb = Self::estimate_mb(app, ram, &f);
                        let node = Self::pick(PlacementPolicy::Spread, &capacities, &planned, mb)
                            .ok_or_else(|| {
                                Error::Config(format!(
                                    "no node can fit `{f}` ({mb:.0} MiB) at deployment"
                                ))
                            })?;
                        planned[node] += mb;
                        plan.insert(f, NodeId(node as u64));
                    }
                }
                None => {
                    return Err(Error::Config(format!(
                        "no node can fit `{}` ({unit_mb:.0} MiB) at deployment",
                        unit.join("+")
                    )));
                }
            }
        }
        Ok(plan)
    }

    /// Idle footprint estimate of a singleton instance of `function`.
    fn estimate_mb(app: &AppSpec, ram: &RamParams, function: &str) -> f64 {
        let code = app.function(function).map(|f| f.code_mb).unwrap_or(ram.per_function_mb);
        ram.base_instance_mb + code
    }

    /// The one placement kernel (deployment planning over *planned* loads,
    /// live placement over ledger loads): index of the chosen node, None
    /// if none fits.  BinPack fills the most-loaded fitting node, the
    /// others take the most headroom; ties go to the lowest id.
    fn pick(
        policy: PlacementPolicy,
        capacities: &[f64],
        planned: &[f64],
        need_mb: f64,
    ) -> Option<usize> {
        let fits =
            |i: usize| capacities[i] <= 0.0 || planned[i] + need_mb <= capacities[i];
        let candidates = (0..planned.len()).filter(|&i| fits(i));
        match policy {
            PlacementPolicy::BinPack => candidates.max_by(|&a, &b| {
                planned[a]
                    .partial_cmp(&planned[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(b.cmp(&a))
            }),
            PlacementPolicy::Spread | PlacementPolicy::FusionAffinity => {
                candidates.min_by(|&a, &b| {
                    planned[a]
                        .partial_cmp(&planned[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::config::PlatformConfig;
    use crate::exec::run_virtual;
    use std::rc::Rc;

    fn scheduler(n: usize, capacity: f64, policy: PlacementPolicy) -> Scheduler {
        let mut cfg = PlatformConfig::tiny();
        cfg.cluster.nodes = n;
        cfg.cluster.node_capacity_mb = capacity;
        cfg.cluster.placement = policy;
        Scheduler::new(policy, Cluster::new(&Rc::new(cfg)))
    }

    #[test]
    fn bin_pack_fills_one_node_first() {
        let s = scheduler(3, 0.0, PlacementPolicy::BinPack);
        let ram = PlatformConfig::tiny().ram;
        let plan = s.place_app(&apps::chain(4), &ram).unwrap();
        // uncapped bin-pack puts everything on node 0
        assert!(plan.values().all(|&n| n == NodeId(0)), "{plan:?}");
    }

    #[test]
    fn spread_balances_across_nodes() {
        let s = scheduler(3, 0.0, PlacementPolicy::Spread);
        let ram = PlatformConfig::tiny().ram;
        let plan = s.place_app(&apps::chain(6), &ram).unwrap();
        // 6 equal functions over 3 nodes -> 2 per node
        for node in 0..3 {
            let count = plan.values().filter(|&&n| n == NodeId(node)).count();
            assert_eq!(count, 2, "{plan:?}");
        }
    }

    #[test]
    fn fusion_affinity_keeps_sync_groups_whole() {
        let s = scheduler(3, 0.0, PlacementPolicy::FusionAffinity);
        let ram = PlatformConfig::tiny().ram;
        // iot-heavy: {ingest, model, refine} and {notify, persist}
        let plan = s.place_app(&apps::iot_heavy(), &ram).unwrap();
        assert_eq!(plan["ingest"], plan["model"]);
        assert_eq!(plan["model"], plan["refine"]);
        assert_eq!(plan["notify"], plan["persist"]);
        // the two groups spread onto different nodes
        assert_ne!(plan["ingest"], plan["persist"], "{plan:?}");
    }

    #[test]
    fn fusion_affinity_degrades_to_spread_when_a_group_cannot_fit() {
        // chain(4) group needs 4 x (58 + 12) = 280 MiB; cap at 200 forces
        // the per-function fallback, which spreads 70 MiB singletons
        let s = scheduler(2, 200.0, PlacementPolicy::FusionAffinity);
        let ram = PlatformConfig::tiny().ram;
        let plan = s.place_app(&apps::chain(4), &ram).unwrap();
        let on0 = plan.values().filter(|&&n| n == NodeId(0)).count();
        let on1 = plan.values().filter(|&&n| n == NodeId(1)).count();
        assert_eq!(on0 + on1, 4);
        assert!(on0 > 0 && on1 > 0, "fallback must still use both nodes: {plan:?}");
    }

    #[test]
    fn place_errors_when_nothing_fits() {
        run_virtual(async {
            let s = scheduler(2, 50.0, PlacementPolicy::Spread);
            assert!(s.place(80.0).is_err());
            assert!(s.place(40.0).is_ok());
        });
    }

    #[test]
    fn live_placement_tracks_actual_load() {
        run_virtual(async {
            let s = scheduler(2, 0.0, PlacementPolicy::Spread);
            let cluster = s.cluster.clone();
            let img = cluster
                .control()
                .register_image(crate::containerd::FsManifest::function_code("a", 8), vec![(
                    "a".into(),
                    9.0,
                )]);
            // empty cluster: lowest id wins
            assert_eq!(s.place(10.0).unwrap(), NodeId(0));
            let _i = cluster.launch_on(NodeId(0), img).unwrap();
            crate::exec::sleep_ms(2_000.0).await;
            // node 0 now carries 67 MiB -> spread prefers node 1,
            // bin-pack (same cluster) prefers node 0
            assert_eq!(s.place(10.0).unwrap(), NodeId(1));
            let packer = Scheduler::new(PlacementPolicy::BinPack, cluster);
            assert_eq!(packer.place(10.0).unwrap(), NodeId(0));
        });
    }
}
