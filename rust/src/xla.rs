//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The real `xla` crate links the PJRT CPU plugin and cannot be vendored
//! into this zero-dependency build.  This module preserves the exact API
//! surface [`crate::runtime`] uses so the crate compiles hermetically;
//! every entry point that would need the real runtime returns an
//! unavailability error instead of executing HLO.
//!
//! Consequences, by design:
//!
//! * [`crate::runtime::ArtifactSet::load`] fails with a clear message, so
//!   `ComputeMode::Live` / `ComputeMode::Replay` are unusable in this
//!   build — pass `--no-compute` (i.e. `ComputeMode::Disabled`) instead.
//! * The PJRT-dependent tests in `rust/tests/artifact_parity.rs` self-skip
//!   when `artifacts/` is absent, so `cargo test` stays green.
//!
//! Swapping the real bindings back in is a one-line change: delete this
//! module (and the `use crate::xla;` imports) and add the `xla` crate to
//! `Cargo.toml`.

use std::fmt;

/// False in this stub build; true when the real PJRT bindings are linked.
/// Runtime gates (parity tests, benches) must check this in addition to
/// `artifacts/` existing before exercising PJRT-backed compute.
pub const PJRT_AVAILABLE: bool = false;

/// Error type mirroring `xla::Error` (stringly, Display-able).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT bindings unavailable in this build (offline `xla` stub); \
         use ComputeMode::Disabled / --no-compute"
            .into(),
    ))
}

/// PJRT client handle (CPU-only in the real crate).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<std::path::Path>) -> Result<HloModuleProto, Error> {
        let _ = path.as_ref();
        unavailable()
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors the real signature: one buffer matrix per device partition.
    pub fn execute<L: AsExecuteInput>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Argument types accepted by [`PjRtLoadedExecutable::execute`].
pub trait AsExecuteInput {}

impl AsExecuteInput for Literal {}

/// A device buffer holding an execution result.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Host-side tensor literal (f32-only in this stub, which is all the
/// artifact pipeline produces).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    values: Vec<f32>,
}

impl Literal {
    /// Rank-1 literal from a float slice.
    pub fn vec1(values: &[f32]) -> Literal {
        Literal { values: values.to_vec() }
    }

    /// Reshape (element count must be preserved by the caller; the stub
    /// stores data flat, so this is a no-op view change).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(self.clone())
    }

    /// First element of a tuple literal (aot.py lowers with
    /// `return_tuple=True`; the stub stores tuples flat).
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Ok(self.clone())
    }

    /// Extract the raw values.
    pub fn to_vec<T: FromElement>(&self) -> Result<Vec<T>, Error> {
        Ok(self.values.iter().map(|&v| T::from_f32(v)).collect())
    }
}

/// Element conversion for [`Literal::to_vec`].
pub trait FromElement {
    fn from_f32(v: f32) -> Self;
}

impl FromElement for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_entry_points_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("no-compute"));
    }

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0]);
        let reshaped = lit.reshape(&[3, 1]).unwrap();
        let values = reshaped.to_tuple1().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(values, vec![1.0, 2.0, 3.0]);
    }
}
