//! `provuse` — CLI launcher for the Provuse reproduction.
//!
//! Subcommands map to DESIGN.md's experiment index:
//!
//! ```text
//! provuse figure5             regenerate paper Fig. 5 (IOT/tinyFaaS series)
//! provuse figure6             regenerate paper Fig. 6 + §5.2 tables
//! provuse ram-table           TAB-RAM (RAM columns of the matrix)
//! provuse sweep --dim X       ablations: rate | hop | policy
//! provuse experiment ...      one custom run
//! provuse apps [--graph APP]  list apps / emit DOT call graphs (Figs. 3-4)
//! provuse validate-artifacts  PJRT vs python golden parity check
//! provuse dump-config         print platform calibration as JSON
//! ```

use provuse::config::{
    ComputeMode, MergePolicyKind, PlacementPolicy, PlannerKind, PlatformConfig,
    PlatformKind, SplitPolicyKind, WorkloadConfig,
};
use provuse::error::Result;
use provuse::util::args::Args;
use provuse::{apps, experiments, runtime};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn workload_from(args: &Args) -> Result<WorkloadConfig> {
    let paper = WorkloadConfig::paper();
    Ok(WorkloadConfig {
        requests: args.u64_or("requests", paper.requests)?,
        rate_rps: args.f64_or("rate", paper.rate_rps)?,
        seed: args.u64_or("seed", paper.seed)?,
        timeout_ms: args.f64_or("timeout-ms", paper.timeout_ms)?,
    })
}

fn compute_from(args: &Args) -> ComputeMode {
    if args.has("live") {
        ComputeMode::Live
    } else if args.has("no-compute") {
        ComputeMode::Disabled
    } else {
        ComputeMode::Replay
    }
}

/// Apply the fusion/defusion policy flags shared by `experiment` and
/// `serve` to a platform config (`figure7` maps the subset that makes
/// sense for its fixed scenario onto `Fig7Params` itself).
fn apply_fusion_flags(args: &Args, config: &mut PlatformConfig) -> Result<()> {
    let f = &mut config.fusion;
    f.min_observations = args.u32_or("min-observations", f.min_observations)?;
    f.cooldown_ms = args.f64_or("cooldown-ms", f.cooldown_ms)?;
    f.max_group_size = args.u64_or("max-group-size", f.max_group_size as u64)? as usize;
    f.max_group_ram_mb = args.f64_or("max-group-ram", f.max_group_ram_mb)?;
    f.split_p95_regression = args.f64_or("split-regression", f.split_p95_regression)?;
    f.split_hysteresis_windows = args.u32_or("hysteresis", f.split_hysteresis_windows)?;
    f.feedback_interval_ms = args.f64_or("feedback-interval-ms", f.feedback_interval_ms)?;
    // `--cost-model` alone switches the controller objective; it also
    // accepts an explicit value (`--cost-model threshold` to force PR 1
    // semantics from a wrapper script)
    if let Some(policy) = args.flag("cost-model") {
        f.split_policy = SplitPolicyKind::parse(policy)?;
    }
    // `--merge-policy cost` switches admission to the merge-side planner;
    // `--merge-policy observation-count` forces the seed behavior
    if let Some(policy) = args.flag("merge-policy") {
        f.merge_policy = MergePolicyKind::parse(policy)?;
    }
    f.cost.merge_threshold = args.f64_or("merge-threshold", f.cost.merge_threshold)?;
    if args.has("auto-tune") {
        f.auto_tune = true;
    }
    f.cost.evict_threshold = args.f64_or("evict-threshold", f.cost.evict_threshold)?;
    f.cost.w_latency = args.f64_or("w-latency", f.cost.w_latency)?;
    f.cost.w_ram = args.f64_or("w-ram", f.cost.w_ram)?;
    f.cost.w_gbs = args.f64_or("w-gbs", f.cost.w_gbs)?;
    if args.has("no-defusion") {
        f.defusion = false;
    }
    if args.has("no-transitive") {
        f.transitive = false;
    }
    // `--planner global` swaps the greedy per-tick emissions for the
    // periodic whole-partition re-planner; `--replan-ticks N` sets its
    // cadence in feedback ticks
    if let Some(planner) = args.flag("planner") {
        f.planner = PlannerKind::parse(planner)?;
    }
    f.replan_interval_ticks = args.u32_or("replan-ticks", f.replan_interval_ticks)?;
    Ok(())
}

/// Apply the cluster flags shared by `experiment`, `serve`, and `figure8`.
fn apply_cluster_flags(args: &Args, config: &mut PlatformConfig) -> Result<()> {
    let c = &mut config.cluster;
    c.nodes = args.u64_or("nodes", c.nodes as u64)? as usize;
    c.node_capacity_mb = args.f64_or("node-capacity", c.node_capacity_mb)?;
    // --shards N: partition the simulation core into N per-node lanes
    // (schedules stay bit-identical to --shards 1 for a pinned seed)
    c.shards = args.u64_or("shards", c.shards as u64)?.max(1) as usize;
    if let Some(policy) = args.flag("placement") {
        c.placement = PlacementPolicy::parse(policy)?;
    }
    config.latency.cross_node_ms =
        args.f64_or("cross-node-ms", config.latency.cross_node_ms)?;
    Ok(())
}

/// Apply the replica-scaling flags shared by `experiment` and `serve`.
/// All defaults are the seed's inert values — a command line that never
/// mentions a scaling flag runs the single-instance platform bit for bit.
fn apply_scaling_flags(args: &Args, config: &mut PlatformConfig) -> Result<()> {
    let s = &mut config.scaling;
    s.replicas_max = args.u32_or("replicas-max", s.replicas_max)?;
    s.replicas_min = args.u32_or("replicas-min", s.replicas_min)?;
    s.target_inflight = args.u32_or("target-inflight", s.target_inflight)?;
    s.scale_interval_ms = args.f64_or("scale-interval-ms", s.scale_interval_ms)?;
    s.idle_horizon_ms = args.f64_or("idle-horizon-ms", s.idle_horizon_ms)?;
    s.warm_pool = args.u64_or("warm-pool", s.warm_pool as u64)? as usize;
    s.warm_attach_ms = args.f64_or("warm-attach-ms", s.warm_attach_ms)?;
    s.concurrency = args.u32_or("concurrency", s.concurrency)?;
    Ok(())
}

/// Apply the request-tracing flags shared by `experiment` and `serve`.
/// Defaults are the seed's inert values: a command line that never
/// mentions a trace flag runs with the tracer disabled entirely.
fn apply_trace_flags(args: &Args, config: &mut PlatformConfig) -> Result<()> {
    let t = &mut config.trace;
    t.sample_every = args.u64_or("trace-sample", t.sample_every)?;
    t.max_traces = args.u64_or("trace-max", t.max_traces as u64)? as usize;
    t.window_ms = args.f64_or("trace-window-ms", t.window_ms)?;
    Ok(())
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("figure5") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/fig5"));
            let fig = experiments::fig5::run(&out, workload_from(args)?, compute_from(args))?;
            println!("{}", fig.render());
            println!("outputs written to {}", out.display());
            Ok(())
        }
        Some("figure6") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/fig6"));
            let fig = experiments::fig6::run(&out, workload_from(args)?, compute_from(args))?;
            println!("{}", fig.render());
            println!("outputs written to {}", out.display());
            Ok(())
        }
        Some("figure7") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/fig7"));
            let app = experiments::fig7::Fig7App::parse(&args.str_or("app", "chain"))?;
            let mut p = experiments::fig7::Fig7Params::for_app(app, args.has("smoke"));
            p.compute = compute_from(args);
            p.seed = args.u64_or("seed", p.seed)?;
            p.calm_rps = args.f64_or("calm-rps", p.calm_rps)?;
            p.pressure_rps = args.f64_or("pressure-rps", p.pressure_rps)?;
            p.max_group_ram_mb = args.f64_or("max-group-ram", p.max_group_ram_mb)?;
            p.split_p95_regression =
                args.f64_or("split-regression", p.split_p95_regression)?;
            p.cooldown_ms = args.f64_or("cooldown-ms", p.cooldown_ms)?;
            p.feedback_interval_ms =
                args.f64_or("feedback-interval-ms", p.feedback_interval_ms)?;
            p.hysteresis = args.u32_or("hysteresis", p.hysteresis)?;
            p.min_observations = args.u32_or("min-observations", p.min_observations)?;
            p.evict_threshold = args.f64_or("evict-threshold", p.evict_threshold)?;
            p.w_latency = args.f64_or("w-latency", p.w_latency)?;
            p.w_ram = args.f64_or("w-ram", p.w_ram)?;
            p.w_gbs = args.f64_or("w-gbs", p.w_gbs)?;
            // mixed scenario: `--merge-policy observation-count` runs the
            // fuse->defuse flap negative control
            if let Some(policy) = args.flag("merge-policy") {
                p.merge_policy = MergePolicyKind::parse(policy)?;
            }
            p.merge_threshold = args.f64_or("merge-threshold", p.merge_threshold)?;
            if args.has("auto-tune") {
                p.auto_tune = true;
            }
            p.cold_rps = args.f64_or("cold-rps", p.cold_rps)?;
            for flag in ["no-defusion", "no-transitive", "max-group-size", "cost-model"] {
                if args.has(flag) {
                    return Err(provuse::Error::Config(format!(
                        "--{flag} is not applicable to figure7 (each scenario fixes its \
                         own policy); use `experiment` instead"
                    )));
                }
            }
            let fig = experiments::fig7::run(&out, p)?;
            println!("{}", fig.render());
            println!("outputs written to {}", out.display());
            if !fig.passed() {
                return Err(provuse::Error::Runtime(
                    "FIG7 feedback-loop checks failed".into(),
                ));
            }
            Ok(())
        }
        Some("figure8") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/fig8"));
            let app = experiments::fig8::Fig8App::parse(&args.str_or("app", "chain"))?;
            let mut p = experiments::fig8::Fig8Params::for_app(app, args.has("smoke"));
            p.compute = compute_from(args);
            p.seed = args.u64_or("seed", p.seed)?;
            p.nodes = args.u64_or("nodes", p.nodes as u64)? as usize;
            if let Some(policy) = args.flag("placement") {
                p.placement = PlacementPolicy::parse(policy)?;
            }
            p.node_capacity_mb = args.f64_or("node-capacity", p.node_capacity_mb)?;
            p.group_ram_cap_mb = args.f64_or("max-group-ram", p.group_ram_cap_mb)?;
            p.calm_rps = args.f64_or("calm-rps", p.calm_rps)?;
            p.pressure_rps = args.f64_or("pressure-rps", p.pressure_rps)?;
            p.cooldown_ms = args.f64_or("cooldown-ms", p.cooldown_ms)?;
            p.feedback_interval_ms =
                args.f64_or("feedback-interval-ms", p.feedback_interval_ms)?;
            p.hysteresis = args.u32_or("hysteresis", p.hysteresis)?;
            p.min_observations = args.u32_or("min-observations", p.min_observations)?;
            p.cross_node_ms = args.f64_or("cross-node-ms", p.cross_node_ms)?;
            let fig = experiments::fig8::run(&out, p)?;
            println!("{}", fig.render());
            println!("outputs written to {}", out.display());
            if !fig.passed() {
                return Err(provuse::Error::Runtime(
                    "FIG8 cluster checks failed".into(),
                ));
            }
            Ok(())
        }
        Some("figure9") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/fig9"));
            let mut p = experiments::fig9::Fig9Params::defaults(args.has("smoke"));
            p.compute = compute_from(args);
            p.requests = args.u64_or("requests", p.requests)?;
            p.rate_rps = args.f64_or("rate", p.rate_rps)?;
            p.seed = args.u64_or("seed", p.seed)?;
            p.chain_len = args.u64_or("chain", p.chain_len as u64)? as usize;
            p.feedback_interval_ms =
                args.f64_or("feedback-interval-ms", p.feedback_interval_ms)?;
            p.min_observations = args.u32_or("min-observations", p.min_observations)?;
            p.shards = args.u64_or("shards", p.shards as u64)?.max(1) as usize;
            p.nodes = args.u64_or("nodes", p.nodes as u64)?.max(1) as usize;
            p.trace_sample = args.u64_or("trace-sample", p.trace_sample)?;
            // --threads on: real worker threads over the tenant fleet
            // (a bare `--threads` also arms it)
            p.threads = match args.flag("threads") {
                Some("on") | Some("true") => true,
                Some("off") | None => false,
                Some(other) => {
                    return Err(provuse::Error::Config(format!(
                        "--threads expects on|off, got `{other}`"
                    )))
                }
            };
            if args.has("no-parity") {
                p.parity = false;
            }
            let fig = experiments::fig9::run(&out, p)?;
            println!("{}", fig.render());
            println!("outputs written to {}", out.display());
            if !fig.passed() {
                return Err(provuse::Error::Runtime("FIG9 scale checks failed".into()));
            }
            Ok(())
        }
        Some("figure10") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/fig10"));
            let mut p = experiments::fig10::Fig10Params::defaults(args.has("smoke"));
            p.compute = compute_from(args);
            p.requests = args.u64_or("requests", p.requests)?;
            p.burst_rps = args.f64_or("burst-rps", p.burst_rps)?;
            p.timeout_ms = args.f64_or("timeout-ms", p.timeout_ms)?;
            p.seed = args.u64_or("seed", p.seed)?;
            p.replicas_max = args.u32_or("replicas-max", p.replicas_max)?;
            p.target_inflight = args.u32_or("target-inflight", p.target_inflight)?;
            p.scale_interval_ms = args.f64_or("scale-interval-ms", p.scale_interval_ms)?;
            p.warm_pool = args.u64_or("warm-pool", p.warm_pool as u64)? as usize;
            p.warm_attach_ms = args.f64_or("warm-attach-ms", p.warm_attach_ms)?;
            p.concurrency = args.u32_or("concurrency", p.concurrency)?;
            if args.has("no-parity") {
                p.parity = false;
            }
            let fig = experiments::fig10::run(&out, p)?;
            println!("{}", fig.render());
            println!("outputs written to {}", out.display());
            if !fig.passed() {
                return Err(provuse::Error::Runtime(
                    "FIG10 replica-scaling checks failed".into(),
                ));
            }
            Ok(())
        }
        Some("figure11") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/fig11"));
            let mut p = experiments::fig11::Fig11Params::defaults(args.has("smoke"));
            p.compute = compute_from(args);
            p.requests = args.u64_or("requests", p.requests)?;
            p.rate_rps = args.f64_or("rate", p.rate_rps)?;
            p.seed = args.u64_or("seed", p.seed)?;
            p.feedback_interval_ms =
                args.f64_or("feedback-interval-ms", p.feedback_interval_ms)?;
            p.replan_ticks = args.u32_or("replan-ticks", p.replan_ticks)?.max(1);
            p.min_observations = args.u32_or("min-observations", p.min_observations)?;
            let fig = experiments::fig11::run(&out, p)?;
            println!("{}", fig.render());
            println!("outputs written to {}", out.display());
            if !fig.passed() {
                return Err(provuse::Error::Runtime(
                    "FIG11 greedy-vs-global checks failed".into(),
                ));
            }
            Ok(())
        }
        Some("figure12") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/fig12"));
            let mut p = experiments::fig12::Fig12Params::defaults(args.has("smoke"));
            p.chain_len = args.u64_or("chain", p.chain_len as u64)?.max(2) as usize;
            p.measured = args.u64_or("requests", p.measured)?.max(1);
            p.seed = args.u64_or("seed", p.seed)?;
            let fig = experiments::fig12::run(&out, p)?;
            println!("{}", fig.render());
            println!("outputs written to {}", out.display());
            // `--trace-out PATH` additionally copies the fused arm's Chrome
            // trace-event JSON to an explicit path (CI artifact upload)
            if let Some(path) = args.flag("trace-out") {
                experiments::write_output(
                    std::path::Path::new(path),
                    &fig.fused.chrome_json,
                )?;
            }
            if !fig.passed() {
                return Err(provuse::Error::Runtime(
                    "FIG12 exact-attribution checks failed".into(),
                ));
            }
            Ok(())
        }
        Some("ram-table") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/ram"));
            let fig = experiments::fig6::run(&out, workload_from(args)?, compute_from(args))?;
            println!("TAB-RAM: platform RAM (time-weighted mean, MiB)\n");
            println!("| config | vanilla | fusion | reduction | paper |");
            println!("|--------|--------:|-------:|----------:|------:|");
            for c in &fig.cells {
                println!(
                    "| {}/{} | {:.0} | {:.0} | {:.1}% | ~{:.0}% |",
                    c.platform.name(),
                    c.app,
                    c.vanilla.ram_mean_mb,
                    c.fusion.ram_mean_mb,
                    c.ram_reduction_pct(),
                    c.paper.ram_reduction_pct
                );
            }
            println!(
                "| average | | | {:.1}% | 53.6% |",
                fig.mean_ram_reduction_pct()
            );
            Ok(())
        }
        Some("cost-table") => {
            let out = std::path::PathBuf::from(args.str_or("out", "results/cost"));
            let fig = experiments::fig6::run(&out, workload_from(args)?, compute_from(args))?;
            println!("{}", fig.render_cost());
            Ok(())
        }
        Some("sweep") => {
            let dim = args.str_or("dim", "rate");
            let out = std::path::PathBuf::from(args.str_or("out", "results/sweeps"));
            let requests = args.u64_or("requests", 2_000)?;
            let sweep = experiments::sweep::run(&dim, &out, requests, compute_from(args))?;
            println!("{}", sweep.render());
            Ok(())
        }
        Some("experiment") => {
            let kind = PlatformKind::parse(&args.str_or("platform", "tiny"))?;
            let app = provuse::apps::by_name(&args.str_or("app", "iot"))?;
            let mut config = PlatformConfig::of_kind(kind).with_compute(compute_from(args));
            apply_fusion_flags(args, &mut config)?;
            apply_cluster_flags(args, &mut config)?;
            apply_scaling_flags(args, &mut config)?;
            apply_trace_flags(args, &mut config)?;
            if args.has("vanilla") {
                config = config.vanilla();
            }
            let result = experiments::run_custom(app, config, workload_from(args)?)?;
            println!("{}: {}", result.label(), result.report.summary());
            println!(
                "  RAM mean {:.0} MiB, {} merges, {} splits, {} final instances, {} inline calls",
                result.ram_mean_mb,
                result.merges.len(),
                result.splits.len(),
                result.final_instances,
                result.inline_calls
            );
            if result.trace_violations > 0 {
                return Err(provuse::Error::Runtime(format!(
                    "{} trace conservation violations",
                    result.trace_violations
                )));
            }
            // `--trace-out PATH` dumps the retained traces as Chrome
            // trace-event JSON (open in chrome://tracing or Perfetto)
            if let Some(path) = args.flag("trace-out") {
                let json = result.trace_chrome_json.as_deref().ok_or_else(|| {
                    provuse::Error::Config(
                        "--trace-out requires tracing armed (--trace-sample N > 0)".into(),
                    )
                })?;
                experiments::write_output(std::path::Path::new(path), json)?;
                println!("  traces written to {path}");
            }
            Ok(())
        }
        Some("apps") => {
            if let Some(name) = args.flag("graph") {
                let app = apps::by_name(name)?;
                println!("{}", app.to_dot());
            } else {
                println!("available applications:");
                for name in apps::APP_NAMES {
                    let app = apps::by_name(name)?;
                    println!(
                        "  {:<6} {} functions, entry `{}`, fusion groups: {:?}",
                        name,
                        app.len(),
                        app.entry,
                        app.sync_fusion_groups()
                    );
                }
            }
            Ok(())
        }
        Some("validate-artifacts") => {
            let dir = args.str_or("dir", "artifacts");
            let set = runtime::ArtifactSet::load(&dir)?;
            let results = set.validate(1e-4)?;
            let mut all_ok = true;
            println!("cross-layer parity (rust/PJRT vs python golden):");
            for v in &results {
                println!(
                    "  {:>16}: max |err| = {:.2e}  {}",
                    v.name,
                    v.max_abs_err,
                    if v.ok { "OK" } else { "FAIL" }
                );
                all_ok &= v.ok;
            }
            if !all_ok {
                return Err(provuse::Error::Runtime("artifact validation failed".into()));
            }
            println!("{} artifacts OK", results.len());
            Ok(())
        }
        Some("serve") => {
            let kind = PlatformKind::parse(&args.str_or("platform", "tiny"))?;
            let app = apps::by_name(&args.str_or("app", "iot"))?;
            let port = args.u64_or("port", 8080)? as u16;
            let scale = args.f64_or("scale", 1.0)?;
            let mut config = PlatformConfig::of_kind(kind)
                .with_compute(if args.has("no-compute") {
                    ComputeMode::Disabled
                } else {
                    ComputeMode::Live
                })
                .scale_latency(scale);
            apply_fusion_flags(args, &mut config)?;
            apply_cluster_flags(args, &mut config)?;
            apply_scaling_flags(args, &mut config)?;
            apply_trace_flags(args, &mut config)?;
            if args.has("vanilla") {
                config = config.vanilla();
            }
            provuse::httpfront::serve(app, config, port, None)
        }
        Some("dump-config") => {
            let kind = PlatformKind::parse(&args.str_or("platform", "tiny"))?;
            println!("{}", PlatformConfig::of_kind(kind).to_json().to_string());
            Ok(())
        }
        Some(other) => Err(provuse::Error::Config(format!("unknown command `{other}`"))),
        None => {
            println!(
                "provuse — platform-side function fusion (paper reproduction)\n\n\
                 usage: provuse <command> [flags]\n\n\
                 commands:\n\
                 \x20 figure5              paper Fig. 5 (IOT/tinyFaaS latency series)\n\
                 \x20 figure6              paper Fig. 6 + §5.2 latency table\n\
                 \x20 figure7 [--smoke]    ours: feedback loop; --app chain (RAM-cap split,\n\
                 \x20   [--app chain|iot|  re-fuse), --app iot (cost-model partial defusion),\n\
                 \x20    mixed]            or --app mixed (merge-side admission planner;\n\
                 \x20                      --merge-policy observation-count = flap control)\n\
                 \x20 figure8 [--smoke]    ours: multi-node cluster (--nodes N,\n\
                 \x20   [--placement P]    fusion-affinity co-location + node-pressure\n\
                 \x20                      migration; --placement spread = measured\n\
                 \x20                      cross-node negative control)\n\
                 \x20 figure9 [--smoke]    ours: telemetry pipeline at 10^6 requests\n\
                 \x20   [--no-parity]      (windowed recording, bounded memory, verdict\n\
                 \x20   [--shards N]       parity vs full retention; --shards N self-checks\n\
                 \x20   [--nodes N]        1-vs-N-shard transcript parity, then emits\n\
                 \x20   [--threads on]     BENCH_scale.json; --threads on drives a tenant\n\
                 \x20                      fleet on N real worker threads with a\n\
                 \x20                      sequential bit-parity twin)\n\
                 \x20 figure10 [--smoke]   ours: replica sets under burst (warm-pool +\n\
                 \x20   [--no-parity]      cold-boot scale-out with zero drops, scale-in\n\
                 \x20                      to floor, --replicas-max 1 seed-parity trio)\n\
                 \x20 figure11 [--smoke]   ours: greedy vs global re-planning A/B on the\n\
                 \x20   [--replan-ticks N] trap app (greedy locks into a local optimum;\n\
                 \x20                      the global planner's steady state dominates)\n\
                 \x20 figure12 [--smoke]   ours: exact span-level latency attribution\n\
                 \x20   [--chain N]        (unfused vs fused chain on a jitter-free\n\
                 \x20   [--trace-out PATH] fabric: e2e delta == eliminated envelope -\n\
                 \x20                      added inline, in integer ns)\n\
                 \x20 ram-table            §5.2 RAM reductions\n\
                 \x20 cost-table           TAB-COST: double-billing elimination in $\n\
                 \x20 sweep --dim D        ablations (rate|hop|policy|depth|arrival)\n\
                 \x20 experiment           one custom run (--platform, --app, --vanilla)\n\
                 \x20 apps [--graph APP]   app list / DOT call graphs (Figs. 3-4)\n\
                 \x20 validate-artifacts   PJRT vs python golden parity\n\
                 \x20 serve --port P       real HTTP front end (live PJRT compute)\n\
                 \x20 dump-config          print calibration JSON\n\n\
                 common flags: --requests N --rate R --seed S --live --no-compute --out DIR\n\
                 policy flags: --min-observations N --cooldown-ms MS --max-group-size N\n\
                 \x20             --max-group-ram MB --split-regression F --hysteresis N\n\
                 \x20             --feedback-interval-ms MS --no-defusion --no-transitive\n\
                 cost model  : --cost-model [threshold|cost] --evict-threshold F\n\
                 \x20             --w-latency F --w-ram F --w-gbs F\n\
                 merge side  : --merge-policy [observation-count|cost] --merge-threshold F\n\
                 \x20             --auto-tune (hill-climb weights on post-fuse regret)\n\
                 planner     : --planner [greedy|global] --replan-ticks N\n\
                 cluster     : --nodes N --placement [bin-pack|spread|fusion-affinity]\n\
                 \x20             --node-capacity MB --cross-node-ms MS --shards N\n\
                 scaling     : --replicas-max N --replicas-min N --target-inflight N\n\
                 \x20             --scale-interval-ms MS --idle-horizon-ms MS --warm-pool N\n\
                 \x20             --warm-attach-ms MS --concurrency N\n\
                 tracing     : --trace-sample N (1-in-N; 0 = off) --trace-max N\n\
                 \x20             --trace-window-ms MS --trace-out PATH"
            );
            Ok(())
        }
    }
}
