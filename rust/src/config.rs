//! Platform and experiment configuration with the calibration defaults from
//! DESIGN.md §5.  All latency/RAM knobs are data, not code: the benchmark
//! harness sweeps them (`provuse sweep`) to probe the sensitivity of the
//! paper's claims.

use crate::error::{Error, Result};
use crate::metrics::RecordingConfig;
use crate::util::json::Json;

/// Which FaaS platform flavor to assemble (paper §4: tinyFaaS + Kubernetes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlatformKind {
    /// tinyFaaS-like: single-binary gateway, direct container dispatch.
    Tiny,
    /// Kubernetes-like: Service VIP indirection, reconciler-driven deploys.
    Kube,
}

impl PlatformKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlatformKind::Tiny => "tinyfaas",
            PlatformKind::Kube => "kubernetes",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "tiny" | "tinyfaas" => Ok(PlatformKind::Tiny),
            "kube" | "kubernetes" | "k8s" => Ok(PlatformKind::Kube),
            other => Err(Error::Config(format!("unknown platform `{other}`"))),
        }
    }
}

/// How function compute bodies are executed on the request path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// Execute the HLO artifact through PJRT on every invocation.
    Live,
    /// Execute each artifact once at deploy time; replay its output and
    /// charge its profiled duration per invocation (deterministic timing,
    /// used by the large experiment sweeps).
    Replay,
    /// No PJRT at all: charge only spec busy-time (pure-coordination unit
    /// tests that must not depend on `artifacts/`).
    Disabled,
}

/// Latency fabric calibration (virtual-time milliseconds). See DESIGN.md §5.
#[derive(Debug, Clone)]
pub struct LatencyParams {
    /// gateway route lookup + request admission
    pub gateway_ms: f64,
    /// Kubernetes Service VIP / kube-proxy hop (0 for tiny)
    pub service_indirection_ms: f64,
    /// median one-way network latency between instances
    pub net_hop_ms: f64,
    /// lognormal sigma of network latency
    pub net_sigma: f64,
    /// median *additional* one-way latency of a hop that crosses node
    /// boundaries (east-west fabric; 0 disables the surcharge).  Same-node
    /// remote calls pay only `net_hop_ms` (veth/loopback), so a single-node
    /// cluster reproduces the seed latencies exactly.
    pub cross_node_ms: f64,
    /// lognormal sigma of the cross-node surcharge
    pub cross_node_sigma: f64,
    /// envelope (de)serialization fixed cost per remote call
    pub serialize_base_ms: f64,
    /// (de)serialization per-KiB cost
    pub serialize_per_kb_ms: f64,
    /// handler dispatch overhead per invocation (python shim in the paper)
    pub dispatch_ms: f64,
    /// gaussian jitter std on dispatch
    pub dispatch_sigma: f64,
    /// cost of an inlined (fused, same-process) call
    pub inline_call_ms: f64,
    /// container/pod boot latency
    pub boot_ms: f64,
    /// fused image export+union+build latency
    pub image_build_ms: f64,
    /// interval between health checks of a booting instance
    pub health_interval_ms: f64,
    /// consecutive successes required before traffic cutover
    pub health_checks_required: u32,
    /// reconciler poll interval (Kube only; 0 = direct)
    pub reconcile_interval_ms: f64,
}

/// Instance RAM model (MiB). See DESIGN.md §5.
#[derive(Debug, Clone)]
pub struct RamParams {
    /// language runtime + Function Handler baseline per instance
    pub base_instance_mb: f64,
    /// default code+deps footprint per function (specs may override)
    pub per_function_mb: f64,
    /// transient working set per in-flight request
    pub working_per_request_mb: f64,
    /// RAM ledger sampling interval
    pub sample_interval_ms: f64,
}

/// How the cluster scheduler places fresh instances onto nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Fill the most-loaded node that still fits (minimize nodes in use).
    BinPack,
    /// Place on the node with the most headroom (balance load).
    Spread,
    /// Spread *sync fusion groups* as units: functions that the call graph
    /// says will fuse are co-located up front, so fusion never needs a
    /// migration; distinct groups balance across nodes like `Spread`.
    FusionAffinity,
}

impl PlacementPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::BinPack => "bin-pack",
            PlacementPolicy::Spread => "spread",
            PlacementPolicy::FusionAffinity => "fusion-affinity",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "bin-pack" | "binpack" | "pack" => Ok(PlacementPolicy::BinPack),
            "spread" => Ok(PlacementPolicy::Spread),
            "fusion-affinity" | "affinity" => Ok(PlacementPolicy::FusionAffinity),
            other => Err(Error::Config(format!(
                "unknown placement policy `{other}` (available: bin-pack, spread, \
                 fusion-affinity)"
            ))),
        }
    }
}

/// Multi-node cluster shape (`nodes = 1` reproduces the single-host seed
/// platform exactly: no cross-node hops, no capacity pressure, no
/// migrations).
#[derive(Debug, Clone)]
pub struct ClusterParams {
    /// number of nodes (each wraps its own container runtime)
    pub nodes: usize,
    /// per-node RAM capacity (MiB); 0 = uncapped (no node-pressure control)
    pub node_capacity_mb: f64,
    /// how fresh instances are assigned to nodes
    pub placement: PlacementPolicy,
    /// simulation-core lanes (`--shards`): tasks/timers are partitioned by
    /// node across this many shards (`Executor::sharded`).  Schedules are
    /// bit-identical for any value under a pinned seed; 1 = the unsharded
    /// seed executor.  Clamped to at least 1 at use sites.
    pub shards: usize,
}

impl Default for ClusterParams {
    fn default() -> Self {
        ClusterParams {
            nodes: 1,
            node_capacity_mb: 0.0,
            placement: PlacementPolicy::BinPack,
            shards: 1,
        }
    }
}

/// Replica-set capacity management (ISSUE 6).  The defaults are chosen so
/// that an untouched config reproduces the seed's one-instance-per-function
/// behavior **bit for bit**: singleton replica sets never draw from the
/// balancer RNG, the autoscaler loop is not even spawned, no warm pool is
/// booted, and an unlimited concurrency cap makes slot accounting a no-op.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingParams {
    /// hard ceiling on replicas per deployed function (>= 1; 1 = the seed's
    /// single-instance invariant, autoscaler inert)
    pub replicas_max: u32,
    /// floor the autoscaler scales back down to after a burst (>= 1 unless
    /// scale-to-zero overrides it past the idle horizon)
    pub replicas_min: u32,
    /// in-flight requests one replica is expected to absorb; the autoscaler
    /// sizes a set at `ceil(in_flight / target_inflight)`
    pub target_inflight: u32,
    /// autoscaler evaluation interval (virtual ms)
    pub scale_interval_ms: f64,
    /// idle time (no arrivals, nothing in flight) after which a set scales
    /// to zero; 0 disables scale-to-zero (the seed behavior)
    pub idle_horizon_ms: f64,
    /// pre-booted blank instances kept on standby; a scale-up claims one
    /// (paying only `warm_attach_ms`) instead of a cold boot
    pub warm_pool: usize,
    /// cost of attaching a claimed warm instance to a function's image
    /// (code pull + handler registration; orders of magnitude under boot)
    pub warm_attach_ms: f64,
    /// per-replica concurrent-request cap; excess requests queue at the
    /// replica (0 = unlimited, the seed behavior)
    pub concurrency: u32,
}

impl Default for ScalingParams {
    fn default() -> Self {
        ScalingParams {
            replicas_max: 1,
            replicas_min: 1,
            target_inflight: 8,
            scale_interval_ms: 1_000.0,
            idle_horizon_ms: 0.0,
            warm_pool: 0,
            warm_attach_ms: 120.0,
            concurrency: 0,
        }
    }
}

impl ScalingParams {
    /// Whether the autoscaler control loop needs to run at all.  When this
    /// is false (the default config) the platform spawns no scaling task
    /// and the request path is byte-identical to the pre-replica seed.
    pub fn autoscaler_armed(&self) -> bool {
        self.replicas_max > 1 || self.idle_horizon_ms > 0.0
    }
}

/// Which objective the defusion controller optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitPolicyKind {
    /// PR 1 behavior: two independent thresholds (RAM cap + p95 regression)
    /// with hysteresis, whole-group splits only.
    Threshold,
    /// Cost-model-driven (Konflux-style): one weighted objective over
    /// latency regression x RAM pressure x billed GiB-seconds; groups over
    /// `evict_threshold` shed their heaviest member (partial split).
    CostModel,
}

impl SplitPolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            SplitPolicyKind::Threshold => "threshold",
            SplitPolicyKind::CostModel => "cost",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threshold" | "false" => Ok(SplitPolicyKind::Threshold),
            "cost" | "cost-model" | "true" => Ok(SplitPolicyKind::CostModel),
            other => Err(Error::Config(format!(
                "unknown split policy `{other}` (available: threshold, cost)"
            ))),
        }
    }
}

/// Which objective gates fusion *admission* (the merge side of the loop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergePolicyKind {
    /// Seed behavior: a pair is fused once its sync-call observation count
    /// crosses `min_observations` — call frequency is the whole signal.
    ObservationCount,
    /// Cost-aware admission planner (Fusionize/Konflux-style): candidate
    /// pairs are scored with `fusion::cost::CostModel::predict_merge` over
    /// windowed per-function signals (self-times, RAM attribution, billed
    /// GiB-seconds) and fused only when the predicted net benefit clears
    /// `CostParams::merge_threshold` — and never when the predicted fused
    /// working set alone would make the group an immediate eviction
    /// candidate (fuse -> evict churn).
    CostModel,
}

impl MergePolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            MergePolicyKind::ObservationCount => "observation-count",
            MergePolicyKind::CostModel => "cost",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "observation-count" | "observations" | "count" | "false" => {
                Ok(MergePolicyKind::ObservationCount)
            }
            "cost" | "cost-model" | "true" => Ok(MergePolicyKind::CostModel),
            other => Err(Error::Config(format!(
                "unknown merge policy `{other}` (available: observation-count, cost)"
            ))),
        }
    }
}

/// Which planning regime drives topology changes (ISSUE 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerKind {
    /// Seed behavior: pairwise-greedy decisions per feedback tick — each
    /// Fuse/Split/Evict/Migrate is emitted the moment its local signal
    /// trips.  Bit-identical to the pre-planner platform.
    Greedy,
    /// Konflux-style global re-planner: every `replan_interval_ticks`
    /// feedback ticks the observer's windowed signals are snapshotted and a
    /// simulated-annealing search over whole call-graph partitions emits a
    /// plan-diff (ordered fuse/split/evict/migrate actions) executed
    /// through the existing pipelines with a stale-topology abort guard.
    /// All greedy emissions are suppressed; plans are the only source of
    /// topology change.
    Global,
}

impl PlannerKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlannerKind::Greedy => "greedy",
            PlannerKind::Global => "global",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "greedy" => Ok(PlannerKind::Greedy),
            "global" => Ok(PlannerKind::Global),
            other => Err(Error::Config(format!(
                "unknown planner `{other}` (available: greedy, global)"
            ))),
        }
    }
}

/// Cost-model weights and thresholds (used when `split_policy` is
/// [`SplitPolicyKind::CostModel`]; see `fusion::cost`).
#[derive(Debug, Clone)]
pub struct CostParams {
    /// weight on the group's p95 regression vs its pre-fusion baseline
    pub w_latency: f64,
    /// weight on the group's RAM pressure (RAM / reference)
    pub w_ram: f64,
    /// weight on the group's billed GiB-seconds per wall second
    pub w_gbs: f64,
    /// objective value above which the controller evicts the group's
    /// heaviest function (<= 0 disables cost-driven defusion)
    pub evict_threshold: f64,
    /// RAM normalization scale (MiB) when `max_group_ram_mb` is 0
    pub ram_ref_mb: f64,
    /// predicted net benefit a candidate pair must clear before the merge
    /// planner admits it (only read under [`MergePolicyKind::CostModel`];
    /// 0 = fuse whenever benefit covers the RAM penalty)
    pub merge_threshold: f64,
    /// multiplicative hill-climb step the auto-tuner applies to the merge
    /// weights on post-fuse regret (only read when `auto_tune` is on)
    pub tune_step: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            w_latency: 1.0,
            w_ram: 1.0,
            w_gbs: 1.0,
            evict_threshold: 2.0,
            ram_ref_mb: 256.0,
            merge_threshold: 0.0,
            tune_step: 0.25,
        }
    }
}

/// Fusion policy knobs (paper §3: Merger admission).
#[derive(Debug, Clone)]
pub struct FusionParams {
    /// master switch: false = vanilla deployment
    pub enabled: bool,
    /// sync-call observations of a pair before requesting fusion
    pub min_observations: u32,
    /// per-pair cooldown after a failed/aborted fusion
    pub cooldown_ms: f64,
    /// allow fused instances to keep growing (A+B then AB+C)
    pub transitive: bool,
    /// restrict fusion to functions in the same trust domain (paper §6)
    pub respect_trust_domains: bool,
    /// upper bound on functions per fused instance (0 = unlimited)
    pub max_group_size: usize,
    /// feedback controller master switch: allow splitting fused groups
    /// back apart (Fusionize-style closed loop; false = fuse-once)
    pub defusion: bool,
    /// RAM cap per fused instance (MiB); a group exceeding it is split
    /// (0 = unlimited, RAM-triggered defusion disabled)
    pub max_group_ram_mb: f64,
    /// p95 latency regression vs the group's pre-fusion baseline that
    /// triggers defusion, as a fraction (0.5 = split when the trailing
    /// window p95 exceeds baseline x 1.5; <= 0 disables the check)
    pub split_p95_regression: f64,
    /// consecutive feedback windows a violation must persist before a
    /// split is requested (hysteresis against transient spikes)
    pub split_hysteresis_windows: u32,
    /// controller evaluation interval (virtual ms; <= 0 disables the loop)
    pub feedback_interval_ms: f64,
    /// which defusion objective the controller runs
    pub split_policy: SplitPolicyKind,
    /// which admission objective gates `FusionRequest::Fuse` emission
    pub merge_policy: MergePolicyKind,
    /// hill-climb the merge weights online from post-fuse regret (a fuse
    /// that is evicted/split within one cooldown of its cutover penalizes
    /// the weights that admitted it)
    pub auto_tune: bool,
    /// cost-model weights (read under `SplitPolicyKind::CostModel` and/or
    /// `MergePolicyKind::CostModel`)
    pub cost: CostParams,
    /// which planning regime drives topology changes (`--planner`):
    /// greedy per-tick emissions (the seed default, bit-identical to the
    /// pre-planner platform) or the periodic global re-planner
    pub planner: PlannerKind,
    /// feedback ticks between global re-plans (`--replan-ticks`; only read
    /// under [`PlannerKind::Global`], must be >= 1)
    pub replan_interval_ticks: u32,
}

/// Request-tracing knobs (ISSUE 9).  The defaults are seed-inert: with
/// `sample_every == 0` the platform builds a disabled [`crate::trace::Tracer`]
/// — no allocation, no RNG, no clock reads — and the request path is
/// byte-identical to the pre-tracing seed.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceParams {
    /// retain roughly 1-in-N successful request traces by seeded draw
    /// (dropped and window-slowest requests are always retained);
    /// 0 = tracing off entirely (the seed default)
    pub sample_every: u64,
    /// bounded ring of retained traces (oldest evicted first)
    pub max_traces: usize,
    /// aggregation window for the breakdown ledger and the
    /// slowest-in-window retention class (ms)
    pub window_ms: f64,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams { sample_every: 0, max_traces: 256, window_ms: 1_000.0 }
    }
}

impl TraceParams {
    /// Whether the tracer records anything at all.
    pub fn armed(&self) -> bool {
        self.sample_every > 0
    }
}

/// Complete platform assembly configuration.
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    pub kind: PlatformKind,
    pub latency: LatencyParams,
    pub ram: RamParams,
    pub fusion: FusionParams,
    pub cluster: ClusterParams,
    /// replica-set autoscaling / warm-pool knobs (defaults = seed-exact
    /// single-instance behavior)
    pub scaling: ScalingParams,
    pub compute: ComputeMode,
    /// telemetry retention (full = seed-exact CSVs; windowed = bounded
    /// recorder memory for scale runs) + windowed shard shape
    pub recording: RecordingConfig,
    /// request-level span tracing (defaults = tracing off, zero cost)
    pub trace: TraceParams,
    /// directory containing `manifest.json` + HLO artifacts
    pub artifacts_dir: String,
    pub seed: u64,
}

impl PlatformConfig {
    /// tinyFaaS-flavored calibration (DESIGN.md §5).
    pub fn tiny() -> Self {
        PlatformConfig {
            kind: PlatformKind::Tiny,
            latency: LatencyParams {
                gateway_ms: 5.0,
                service_indirection_ms: 0.0,
                net_hop_ms: 2.0,
                net_sigma: 0.25,
                cross_node_ms: 12.0,
                cross_node_sigma: 0.25,
                serialize_base_ms: 1.5,
                serialize_per_kb_ms: 0.06,
                dispatch_ms: 45.0,
                dispatch_sigma: 4.0,
                inline_call_ms: 0.05,
                boot_ms: 1_200.0,
                image_build_ms: 4_000.0,
                health_interval_ms: 250.0,
                health_checks_required: 2,
                reconcile_interval_ms: 0.0,
            },
            ram: RamParams {
                base_instance_mb: 58.0,
                per_function_mb: 9.0,
                working_per_request_mb: 1.5,
                sample_interval_ms: 1_000.0,
            },
            fusion: FusionParams::default_enabled(),
            cluster: ClusterParams::default(),
            scaling: ScalingParams::default(),
            compute: ComputeMode::Replay,
            recording: RecordingConfig::default(),
            trace: TraceParams::default(),
            artifacts_dir: "artifacts".into(),
            seed: 7,
        }
    }

    /// Kubernetes-flavored calibration (DESIGN.md §5).
    pub fn kube() -> Self {
        let mut c = Self::tiny();
        c.kind = PlatformKind::Kube;
        c.latency.gateway_ms = 6.0;
        c.latency.service_indirection_ms = 6.0;
        c.latency.net_hop_ms = 2.5;
        c.latency.net_sigma = 0.30;
        c.latency.cross_node_ms = 14.0;
        c.latency.cross_node_sigma = 0.30;
        c.latency.boot_ms = 2_800.0;
        c.latency.reconcile_interval_ms = 500.0;
        c.ram.base_instance_mb = 72.0;
        c
    }

    pub fn of_kind(kind: PlatformKind) -> Self {
        match kind {
            PlatformKind::Tiny => Self::tiny(),
            PlatformKind::Kube => Self::kube(),
        }
    }

    /// Vanilla (fusion disabled) variant of this config.
    pub fn vanilla(mut self) -> Self {
        self.fusion.enabled = false;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_compute(mut self, mode: ComputeMode) -> Self {
        self.compute = mode;
        self
    }

    /// Set the telemetry recording level (shard shape keeps its default).
    pub fn with_recording(mut self, level: crate::metrics::RecordingLevel) -> Self {
        self.recording.level = level;
        self
    }

    /// Uniformly scale every latency parameter (e.g. 0.1 for a snappy
    /// real-time demo of the live HTTP gateway).
    pub fn scale_latency(mut self, factor: f64) -> Self {
        let l = &mut self.latency;
        for v in [
            &mut l.gateway_ms,
            &mut l.service_indirection_ms,
            &mut l.net_hop_ms,
            &mut l.cross_node_ms,
            &mut l.serialize_base_ms,
            &mut l.serialize_per_kb_ms,
            &mut l.dispatch_ms,
            &mut l.dispatch_sigma,
            &mut l.inline_call_ms,
            &mut l.boot_ms,
            &mut l.image_build_ms,
            &mut l.health_interval_ms,
            &mut l.reconcile_interval_ms,
        ] {
            *v *= factor;
        }
        self
    }
}

impl FusionParams {
    /// Trailing window the merger's baseline-p95 capture looks back over
    /// before a cutover.  Windowed telemetry retention is sized from this
    /// same number (`Platform::deploy`), so the baseline query is always
    /// answered exactly — change it here and both sites follow.
    pub fn baseline_lookback_ms(&self) -> f64 {
        (self.feedback_interval_ms * 10.0).max(10_000.0)
    }

    pub fn default_enabled() -> Self {
        FusionParams {
            enabled: true,
            min_observations: 3,
            cooldown_ms: 10_000.0,
            transitive: true,
            respect_trust_domains: true,
            max_group_size: 0,
            defusion: true,
            max_group_ram_mb: 0.0,
            split_p95_regression: 0.5,
            split_hysteresis_windows: 3,
            feedback_interval_ms: 5_000.0,
            split_policy: SplitPolicyKind::Threshold,
            merge_policy: MergePolicyKind::ObservationCount,
            auto_tune: false,
            cost: CostParams::default(),
            planner: PlannerKind::Greedy,
            replan_interval_ticks: 5,
        }
    }

    pub fn disabled() -> Self {
        FusionParams { enabled: false, ..Self::default_enabled() }
    }
}

/// One benchmark run (paper §5.1: 10 000 requests at 5 rps).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// total requests to issue
    pub requests: u64,
    /// constant open-loop arrival rate (requests/second)
    pub rate_rps: f64,
    /// workload generator seed (payload + arrival jitter)
    pub seed: u64,
    /// per-request response deadline; exceeding counts as failure
    pub timeout_ms: f64,
}

impl WorkloadConfig {
    /// The paper's exact workload: 10 000 requests @ 5 rps.
    pub fn paper() -> Self {
        WorkloadConfig { requests: 10_000, rate_rps: 5.0, seed: 1, timeout_ms: 60_000.0 }
    }

    /// Scaled-down workload for quick tests.
    pub fn smoke(requests: u64) -> Self {
        WorkloadConfig { requests, rate_rps: 20.0, seed: 1, timeout_ms: 60_000.0 }
    }
}

impl PlatformConfig {
    /// Serialize the calibration to JSON (CLI `--dump-config`).
    pub fn to_json(&self) -> Json {
        let l = &self.latency;
        let r = &self.ram;
        let f = &self.fusion;
        let c = &self.cluster;
        let s = &self.scaling;
        Json::obj(vec![
            ("platform", Json::str(self.kind.name())),
            ("seed", Json::Num(self.seed as f64)),
            (
                "scaling",
                Json::obj(vec![
                    ("replicas_max", Json::Num(s.replicas_max as f64)),
                    ("replicas_min", Json::Num(s.replicas_min as f64)),
                    ("target_inflight", Json::Num(s.target_inflight as f64)),
                    ("scale_interval_ms", Json::Num(s.scale_interval_ms)),
                    ("idle_horizon_ms", Json::Num(s.idle_horizon_ms)),
                    ("warm_pool", Json::Num(s.warm_pool as f64)),
                    ("warm_attach_ms", Json::Num(s.warm_attach_ms)),
                    ("concurrency", Json::Num(s.concurrency as f64)),
                ]),
            ),
            (
                "recording",
                Json::obj(vec![
                    ("level", Json::str(self.recording.level.name())),
                    ("bucket_ms", Json::Num(self.recording.bucket_ms)),
                    ("buckets", Json::Num(self.recording.buckets as f64)),
                ]),
            ),
            (
                "trace",
                Json::obj(vec![
                    ("sample_every", Json::Num(self.trace.sample_every as f64)),
                    ("max_traces", Json::Num(self.trace.max_traces as f64)),
                    ("window_ms", Json::Num(self.trace.window_ms)),
                ]),
            ),
            (
                "cluster",
                Json::obj(vec![
                    ("nodes", Json::Num(c.nodes as f64)),
                    ("node_capacity_mb", Json::Num(c.node_capacity_mb)),
                    ("placement", Json::str(c.placement.name())),
                    ("shards", Json::Num(c.shards as f64)),
                ]),
            ),
            (
                "latency_ms",
                Json::obj(vec![
                    ("gateway", Json::Num(l.gateway_ms)),
                    ("service_indirection", Json::Num(l.service_indirection_ms)),
                    ("net_hop", Json::Num(l.net_hop_ms)),
                    ("net_sigma", Json::Num(l.net_sigma)),
                    ("cross_node", Json::Num(l.cross_node_ms)),
                    ("cross_node_sigma", Json::Num(l.cross_node_sigma)),
                    ("serialize_base", Json::Num(l.serialize_base_ms)),
                    ("serialize_per_kb", Json::Num(l.serialize_per_kb_ms)),
                    ("dispatch", Json::Num(l.dispatch_ms)),
                    ("dispatch_sigma", Json::Num(l.dispatch_sigma)),
                    ("inline_call", Json::Num(l.inline_call_ms)),
                    ("boot", Json::Num(l.boot_ms)),
                    ("image_build", Json::Num(l.image_build_ms)),
                    ("health_interval", Json::Num(l.health_interval_ms)),
                    ("reconcile_interval", Json::Num(l.reconcile_interval_ms)),
                ]),
            ),
            (
                "ram_mb",
                Json::obj(vec![
                    ("base_instance", Json::Num(r.base_instance_mb)),
                    ("per_function", Json::Num(r.per_function_mb)),
                    ("working_per_request", Json::Num(r.working_per_request_mb)),
                ]),
            ),
            (
                "fusion",
                Json::obj(vec![
                    ("enabled", Json::Bool(f.enabled)),
                    ("min_observations", Json::Num(f.min_observations as f64)),
                    ("cooldown_ms", Json::Num(f.cooldown_ms)),
                    ("transitive", Json::Bool(f.transitive)),
                    ("max_group_size", Json::Num(f.max_group_size as f64)),
                    ("defusion", Json::Bool(f.defusion)),
                    ("max_group_ram_mb", Json::Num(f.max_group_ram_mb)),
                    ("split_p95_regression", Json::Num(f.split_p95_regression)),
                    (
                        "split_hysteresis_windows",
                        Json::Num(f.split_hysteresis_windows as f64),
                    ),
                    ("feedback_interval_ms", Json::Num(f.feedback_interval_ms)),
                    ("split_policy", Json::str(f.split_policy.name())),
                    ("merge_policy", Json::str(f.merge_policy.name())),
                    ("auto_tune", Json::Bool(f.auto_tune)),
                    ("planner", Json::str(f.planner.name())),
                    ("replan_interval_ticks", Json::Num(f.replan_interval_ticks as f64)),
                    (
                        "cost",
                        Json::obj(vec![
                            ("w_latency", Json::Num(f.cost.w_latency)),
                            ("w_ram", Json::Num(f.cost.w_ram)),
                            ("w_gbs", Json::Num(f.cost.w_gbs)),
                            ("evict_threshold", Json::Num(f.cost.evict_threshold)),
                            ("ram_ref_mb", Json::Num(f.cost.ram_ref_mb)),
                            ("merge_threshold", Json::Num(f.cost.merge_threshold)),
                            ("tune_step", Json::Num(f.cost.tune_step)),
                        ]),
                    ),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kube_is_heavier_than_tiny() {
        let t = PlatformConfig::tiny();
        let k = PlatformConfig::kube();
        assert!(k.latency.gateway_ms >= t.latency.gateway_ms);
        assert!(k.latency.service_indirection_ms > 0.0);
        assert!(k.latency.boot_ms > t.latency.boot_ms);
        assert!(k.ram.base_instance_mb > t.ram.base_instance_mb);
    }

    #[test]
    fn vanilla_disables_fusion_only() {
        let c = PlatformConfig::tiny().vanilla();
        assert!(!c.fusion.enabled);
        assert_eq!(c.latency.gateway_ms, PlatformConfig::tiny().latency.gateway_ms);
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(PlatformKind::parse("k8s").unwrap(), PlatformKind::Kube);
        assert_eq!(PlatformKind::parse("tinyfaas").unwrap(), PlatformKind::Tiny);
        assert!(PlatformKind::parse("lambda").is_err());
    }

    #[test]
    fn config_json_dump_parses() {
        let j = PlatformConfig::kube().to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("platform").unwrap().as_str().unwrap(), "kubernetes");
        assert!(
            v.get("latency_ms").unwrap().get("service_indirection").unwrap().as_f64().unwrap()
                > 0.0
        );
        let fusion = v.get("fusion").unwrap();
        assert!(fusion.get("defusion").is_ok());
        assert_eq!(fusion.get("max_group_ram_mb").unwrap().as_f64().unwrap(), 0.0);
        assert!(fusion.get("feedback_interval_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn split_policy_parses_and_defaults_to_threshold() {
        assert_eq!(FusionParams::default_enabled().split_policy, SplitPolicyKind::Threshold);
        assert_eq!(SplitPolicyKind::parse("cost").unwrap(), SplitPolicyKind::CostModel);
        assert_eq!(SplitPolicyKind::parse("true").unwrap(), SplitPolicyKind::CostModel);
        assert_eq!(SplitPolicyKind::parse("threshold").unwrap(), SplitPolicyKind::Threshold);
        assert!(SplitPolicyKind::parse("greedy").is_err());
    }

    #[test]
    fn cost_params_serialize() {
        let j = PlatformConfig::tiny().to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        let fusion = v.get("fusion").unwrap();
        assert_eq!(fusion.get("split_policy").unwrap().as_str().unwrap(), "threshold");
        let cost = fusion.get("cost").unwrap();
        assert!(cost.get("evict_threshold").unwrap().as_f64().unwrap() > 0.0);
        assert!(cost.get("w_ram").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn merge_policy_parses_and_defaults_to_observation_count() {
        let p = FusionParams::default_enabled();
        assert_eq!(p.merge_policy, MergePolicyKind::ObservationCount);
        assert!(!p.auto_tune);
        assert_eq!(
            MergePolicyKind::parse("observation-count").unwrap(),
            MergePolicyKind::ObservationCount
        );
        assert_eq!(MergePolicyKind::parse("cost").unwrap(), MergePolicyKind::CostModel);
        assert_eq!(MergePolicyKind::parse("true").unwrap(), MergePolicyKind::CostModel);
        assert!(MergePolicyKind::parse("vibes").is_err());
    }

    #[test]
    fn merge_planner_knobs_serialize() {
        let j = PlatformConfig::tiny().to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        let fusion = v.get("fusion").unwrap();
        assert_eq!(
            fusion.get("merge_policy").unwrap().as_str().unwrap(),
            "observation-count"
        );
        assert!(fusion.get("auto_tune").is_ok());
        let cost = fusion.get("cost").unwrap();
        assert_eq!(cost.get("merge_threshold").unwrap().as_f64().unwrap(), 0.0);
        assert!(cost.get("tune_step").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn planner_parses_and_defaults_to_greedy() {
        let p = FusionParams::default_enabled();
        assert_eq!(p.planner, PlannerKind::Greedy, "default must be the greedy seed regime");
        assert!(p.replan_interval_ticks >= 1);
        assert_eq!(PlannerKind::parse("greedy").unwrap(), PlannerKind::Greedy);
        assert_eq!(PlannerKind::parse("global").unwrap(), PlannerKind::Global);
        assert!(PlannerKind::parse("konflux").is_err());
    }

    #[test]
    fn planner_knobs_serialize() {
        let mut c = PlatformConfig::tiny();
        c.fusion.planner = PlannerKind::Global;
        c.fusion.replan_interval_ticks = 7;
        let j = c.to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        let fusion = v.get("fusion").unwrap();
        assert_eq!(fusion.get("planner").unwrap().as_str().unwrap(), "global");
        assert_eq!(fusion.get("replan_interval_ticks").unwrap().as_f64().unwrap(), 7.0);
    }

    #[test]
    fn recording_defaults_to_full_and_serializes() {
        let c = PlatformConfig::tiny();
        assert_eq!(c.recording.level, crate::metrics::RecordingLevel::Full);
        assert!(c.recording.retention_ms() >= 60_000.0);
        let j = c.to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        let rec = v.get("recording").unwrap();
        assert_eq!(rec.get("level").unwrap().as_str().unwrap(), "full");
        assert!(rec.get("bucket_ms").unwrap().as_f64().unwrap() > 0.0);
        let w = c.with_recording(crate::metrics::RecordingLevel::Windowed);
        assert_eq!(w.recording.level, crate::metrics::RecordingLevel::Windowed);
    }

    #[test]
    fn cluster_defaults_to_single_uncapped_node() {
        let c = PlatformConfig::tiny();
        assert_eq!(c.cluster.nodes, 1);
        assert_eq!(c.cluster.node_capacity_mb, 0.0);
        assert_eq!(c.cluster.placement, PlacementPolicy::BinPack);
        assert_eq!(c.cluster.shards, 1, "default must be the unsharded seed executor");
        assert!(c.latency.cross_node_ms > c.latency.net_hop_ms);
    }

    #[test]
    fn placement_policy_parses() {
        assert_eq!(PlacementPolicy::parse("bin-pack").unwrap(), PlacementPolicy::BinPack);
        assert_eq!(PlacementPolicy::parse("spread").unwrap(), PlacementPolicy::Spread);
        assert_eq!(
            PlacementPolicy::parse("fusion-affinity").unwrap(),
            PlacementPolicy::FusionAffinity
        );
        assert_eq!(
            PlacementPolicy::parse("affinity").unwrap(),
            PlacementPolicy::FusionAffinity
        );
        assert!(PlacementPolicy::parse("random").is_err());
    }

    #[test]
    fn cluster_knobs_serialize() {
        let mut c = PlatformConfig::tiny();
        c.cluster.nodes = 3;
        c.cluster.node_capacity_mb = 512.0;
        c.cluster.placement = PlacementPolicy::FusionAffinity;
        c.cluster.shards = 3;
        let j = c.to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        let cl = v.get("cluster").unwrap();
        assert_eq!(cl.get("nodes").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(cl.get("node_capacity_mb").unwrap().as_f64().unwrap(), 512.0);
        assert_eq!(cl.get("placement").unwrap().as_str().unwrap(), "fusion-affinity");
        assert_eq!(cl.get("shards").unwrap().as_f64().unwrap(), 3.0);
        assert!(
            v.get("latency_ms").unwrap().get("cross_node").unwrap().as_f64().unwrap() > 0.0
        );
    }

    #[test]
    fn scaling_defaults_are_seed_inert_and_serialize() {
        let c = PlatformConfig::tiny();
        assert_eq!(c.scaling.replicas_max, 1);
        assert_eq!(c.scaling.replicas_min, 1);
        assert_eq!(c.scaling.warm_pool, 0);
        assert_eq!(c.scaling.concurrency, 0);
        assert_eq!(c.scaling.idle_horizon_ms, 0.0);
        assert!(!c.scaling.autoscaler_armed(), "default config must not arm the autoscaler");
        let j = c.to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        let s = v.get("scaling").unwrap();
        assert_eq!(s.get("replicas_max").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(s.get("warm_pool").unwrap().as_f64().unwrap(), 0.0);
        assert!(s.get("scale_interval_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(s.get("warm_attach_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn autoscaler_arms_on_replica_headroom_or_idle_horizon() {
        let mut s = ScalingParams::default();
        assert!(!s.autoscaler_armed());
        s.replicas_max = 4;
        assert!(s.autoscaler_armed());
        s.replicas_max = 1;
        s.idle_horizon_ms = 30_000.0;
        assert!(s.autoscaler_armed(), "scale-to-zero alone must arm the loop");
    }

    #[test]
    fn trace_defaults_are_seed_inert_and_serialize() {
        let c = PlatformConfig::tiny();
        assert_eq!(c.trace.sample_every, 0, "default config must not arm the tracer");
        assert!(!c.trace.armed());
        assert!(c.trace.max_traces > 0);
        assert!(c.trace.window_ms > 0.0);
        let j = c.to_json().to_string();
        let v = crate::util::json::Json::parse(&j).unwrap();
        let t = v.get("trace").unwrap();
        assert_eq!(t.get("sample_every").unwrap().as_f64().unwrap(), 0.0);
        assert!(t.get("max_traces").unwrap().as_f64().unwrap() > 0.0);
        assert!(t.get("window_ms").unwrap().as_f64().unwrap() > 0.0);
        let armed = TraceParams { sample_every: 64, ..TraceParams::default() };
        assert!(armed.armed());
    }

    #[test]
    fn default_policy_has_defusion_armed_but_ram_cap_off() {
        let p = FusionParams::default_enabled();
        assert!(p.defusion);
        assert_eq!(p.max_group_ram_mb, 0.0);
        assert!(p.split_p95_regression > 0.0);
        assert!(p.split_hysteresis_windows >= 1);
        assert!(p.feedback_interval_ms > 0.0);
    }
}
