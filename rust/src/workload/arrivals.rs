//! Arrival processes for the workload generator.
//!
//! The paper uses a constant rate (k6 `constant-arrival-rate`); the
//! ablation harness additionally exercises Poisson arrivals and
//! on/off bursts (the "bursty workloads" the paper's discussion motivates
//! pre-warming for).

use crate::util::rng::Rng;

/// How request start times are laid out.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Exactly `i / rate` seconds (the paper's workload).
    Constant,
    /// Poisson process: exponential inter-arrival gaps with mean `1/rate`.
    Poisson,
    /// On/off square wave: `burst_factor x rate` during the first half of
    /// every `period_s`, idle during the second half (mean rate preserved).
    Burst { period_s: f64, burst_factor: f64 },
}

impl Arrival {
    pub fn parse(s: &str) -> Option<Arrival> {
        match s {
            "constant" => Some(Arrival::Constant),
            "poisson" => Some(Arrival::Poisson),
            "burst" => Some(Arrival::Burst { period_s: 20.0, burst_factor: 2.0 }),
            _ => None,
        }
    }

    /// Generate the arrival timestamps (ms) of `n` requests at mean
    /// `rate_rps`, deterministically from `seed`.
    pub fn schedule(&self, n: u64, rate_rps: f64, seed: u64) -> Vec<f64> {
        assert!(rate_rps > 0.0);
        match self {
            Arrival::Constant => {
                (0..n).map(|i| i as f64 * 1_000.0 / rate_rps).collect()
            }
            Arrival::Poisson => {
                let mut rng = Rng::new(seed ^ 0xA881);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exponential(rate_rps) * 1_000.0;
                        t
                    })
                    .collect()
            }
            Arrival::Burst { period_s, burst_factor } => {
                // active during [k*P, k*P + P/2) at burst_factor*rate; the
                // fraction of requests per period is unchanged (mean rate
                // preserved) because we compress each period's quota into
                // its active half.
                let period_ms = period_s * 1_000.0;
                let per_period = (rate_rps * period_s).max(1.0);
                let active_rate = rate_rps * burst_factor;
                let active_ms = per_period / active_rate * 1_000.0;
                (0..n)
                    .map(|i| {
                        let k = (i as f64 / per_period).floor();
                        let j = i as f64 - k * per_period;
                        k * period_ms + j / per_period * active_ms.min(period_ms)
                    })
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_evenly_spaced() {
        let s = Arrival::Constant.schedule(5, 10.0, 0);
        assert_eq!(s, vec![0.0, 100.0, 200.0, 300.0, 400.0]);
    }

    #[test]
    fn poisson_mean_rate_close() {
        let n = 20_000;
        let s = Arrival::Poisson.schedule(n, 5.0, 42);
        assert!(s.windows(2).all(|w| w[1] >= w[0]), "must be sorted");
        let span_s = s.last().unwrap() / 1_000.0;
        let measured = n as f64 / span_s;
        assert!((measured - 5.0).abs() < 0.2, "rate {measured}");
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        assert_eq!(
            Arrival::Poisson.schedule(100, 5.0, 1),
            Arrival::Poisson.schedule(100, 5.0, 1)
        );
        assert_ne!(
            Arrival::Poisson.schedule(100, 5.0, 1),
            Arrival::Poisson.schedule(100, 5.0, 2)
        );
    }

    #[test]
    fn burst_compresses_into_active_window() {
        let arr = Arrival::Burst { period_s: 10.0, burst_factor: 2.0 };
        let s = arr.schedule(100, 5.0, 0); // 50 per period, active 5s
        // first period's requests all inside [0, 5s)
        for &t in &s[..50] {
            assert!(t < 5_000.0, "{t}");
        }
        // second period starts at 10s
        assert!(s[50] >= 10_000.0);
        // mean rate preserved: 100 requests within ~20s
        assert!(*s.last().unwrap() < 20_000.0);
    }

    #[test]
    fn parse_names() {
        assert!(matches!(Arrival::parse("poisson"), Some(Arrival::Poisson)));
        assert!(matches!(Arrival::parse("burst"), Some(Arrival::Burst { .. })));
        assert!(Arrival::parse("nope").is_none());
    }
}
