//! k6-like workload generator (paper §5.1): constant-rate open-loop HTTP
//! load with per-request latency capture.
//!
//! Arrivals are scheduled on the virtual clock at exactly `i / rate`
//! seconds (open loop: a slow platform does not slow the arrival process),
//! payloads are seeded per request index, and every completion is recorded
//! in the platform's [`Recorder`](crate::metrics::Recorder).

pub mod arrivals;

use std::cell::RefCell;
use std::rc::Rc;

pub use arrivals::Arrival;

use crate::config::WorkloadConfig;
use crate::error::Result;
use crate::exec;
use crate::platform::Platform;
use crate::util::rng::Rng;
use crate::util::stats::Quantiles;

/// Outcome of one workload run.
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub issued: u64,
    pub ok: u64,
    pub failed: u64,
    /// end-to-end latency quantiles over successful requests (ms)
    pub latency: Quantiles,
    /// virtual duration of the run (ms)
    pub duration_ms: f64,
}

impl WorkloadReport {
    pub fn summary(&self) -> String {
        format!(
            "{} requests ({} ok, {} failed) in {:.1}s: median {:.1} ms, mean {:.1} ms, p95 {:.1} ms, p99 {:.1} ms",
            self.issued,
            self.ok,
            self.failed,
            self.duration_ms / 1e3,
            self.latency.median(),
            self.latency.mean(),
            self.latency.p95(),
            self.latency.p99(),
        )
    }

    /// Pool per-lane reports from a tenant fleet into one aggregate:
    /// counters sum, latency samples are re-sorted into one distribution,
    /// and the duration is the max (the lanes ran concurrently in the
    /// same virtual timeline, not back to back).
    pub fn merged(reports: &[WorkloadReport]) -> WorkloadReport {
        let mut samples = Vec::new();
        let mut merged = WorkloadReport {
            issued: 0,
            ok: 0,
            failed: 0,
            latency: Quantiles::from_samples(Vec::new()),
            duration_ms: 0.0,
        };
        for r in reports {
            merged.issued += r.issued;
            merged.ok += r.ok;
            merged.failed += r.failed;
            merged.duration_ms = merged.duration_ms.max(r.duration_ms);
            samples.extend_from_slice(r.latency.samples());
        }
        merged.latency = Quantiles::from_samples(samples);
        merged
    }
}

/// Deterministic per-request payload (seeded by workload seed + index).
pub fn request_payload(seed: u64, index: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15).fork(index);
    let mut payload = vec![0.0f32; len];
    rng.fill_normal_f32(&mut payload);
    payload
}

/// Drive `cfg` against `platform` with the paper's constant-rate arrivals.
pub async fn run(platform: Rc<Platform>, cfg: WorkloadConfig) -> Result<WorkloadReport> {
    run_with_arrival(platform, cfg, Arrival::Constant).await
}

/// Drive `cfg` against `platform` under an explicit [`Arrival`] process;
/// records latencies into `platform.metrics` and returns a report.
pub async fn run_with_arrival(
    platform: Rc<Platform>,
    cfg: WorkloadConfig,
    arrival: Arrival,
) -> Result<WorkloadReport> {
    run_targeted(platform, cfg, arrival, None).await
}

/// Like [`run_with_arrival`], but aimed at an explicit `target` function
/// instead of the app's entry — the lever for asymmetric per-route
/// pressure (e.g. hammering one interior member of a fused group).
pub async fn run_targeted(
    platform: Rc<Platform>,
    cfg: WorkloadConfig,
    arrival: Arrival,
    target: Option<&str>,
) -> Result<WorkloadReport> {
    let function: Rc<String> = Rc::new(
        target.map(str::to_string).unwrap_or_else(|| platform.app.entry.clone()),
    );
    // trace attribution key for the route under load (the driver — not the
    // dispatcher — owns the trace lifecycle: a timed-out request's future
    // is dropped mid-flight, so only this task can still finalize it)
    let fn_sym = crate::util::intern::Sym::intern(&function);
    let start = exec::now();
    let payload_len = platform.payload_len();
    let ok = Rc::new(RefCell::new(0u64));
    let failed = Rc::new(RefCell::new(0u64));
    let latencies = Rc::new(RefCell::new(Vec::with_capacity(cfg.requests as usize)));
    let schedule = arrival.schedule(cfg.requests, cfg.rate_rps, cfg.seed);

    let mut handles = Vec::with_capacity(cfg.requests as usize);
    for i in 0..cfg.requests {
        // open-loop arrivals: a slow platform does not slow the schedule
        let target_ms = schedule[i as usize];
        let elapsed_ms = exec::now().duration_since(start).as_secs_f64() * 1e3;
        if target_ms > elapsed_ms {
            exec::sleep_ms(target_ms - elapsed_ms).await;
        }

        let payload = request_payload(cfg.seed, i, payload_len);
        let platform = Rc::clone(&platform);
        let function = Rc::clone(&function);
        let ok = Rc::clone(&ok);
        let failed = Rc::clone(&failed);
        let latencies = Rc::clone(&latencies);
        let timeout_ms = cfg.timeout_ms;
        // sharded core: each request's root task enters on the lane of the
        // node serving the entry route (inherit-the-spawner on unsharded
        // runs — route_shard returns 0 and spawn_on(0, _) ≡ spawn there)
        let entry_shard = platform.route_shard(&function);
        handles.push(exec::spawn_on(entry_shard, async move {
            let t0 = exec::now();
            let arrival_ms = platform.metrics.rel_now_ms();
            let trace = platform.tracer.begin_request(fn_sym, arrival_ms);
            let result = exec::timeout(
                std::time::Duration::from_nanos((timeout_ms * 1e6) as u64),
                platform.invoke_function_traced(&function, payload, trace),
            )
            .await;
            let latency_ms = exec::now().duration_since(t0).as_secs_f64() * 1e3;
            match result {
                Ok(Ok(_)) => {
                    *ok.borrow_mut() += 1;
                    latencies.borrow_mut().push(latency_ms);
                    platform.metrics.record_latency(arrival_ms, latency_ms);
                    platform.tracer.finish_ok(trace, latency_ms);
                }
                Ok(Err(e)) => {
                    *failed.borrow_mut() += 1;
                    platform.metrics.bump("request_failures");
                    // drop-cause tagging (ISSUE 9): the aggregate counter
                    // keeps its seed semantics; the per-cause counter makes
                    // the failure auditable from counters_csv alone
                    platform.metrics.bump(e.drop_cause());
                    platform.tracer.finish_dropped(trace);
                }
                Err(_) => {
                    *failed.borrow_mut() += 1;
                    platform.metrics.bump("request_failures");
                    platform.metrics.bump("failed_timeout");
                    platform.tracer.finish_dropped(trace);
                }
            }
        }));
    }
    for h in handles {
        h.await;
    }

    let duration_ms = exec::now().duration_since(start).as_secs_f64() * 1e3;
    let report = WorkloadReport {
        issued: cfg.requests,
        ok: *ok.borrow(),
        failed: *failed.borrow(),
        latency: Quantiles::from_samples(latencies.borrow().clone()),
        duration_ms,
    };
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::config::{ComputeMode, PlatformConfig};
    use crate::exec::run_virtual;

    #[test]
    fn payloads_are_deterministic_and_distinct() {
        let a = request_payload(1, 0, 128);
        let b = request_payload(1, 0, 128);
        let c = request_payload(1, 1, 128);
        let d = request_payload(2, 0, 128);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    #[test]
    fn open_loop_timing_and_all_requests_complete() {
        run_virtual(async {
            let cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled).vanilla();
            let p = crate::platform::Platform::deploy(apps::chain(2), cfg).await.unwrap();
            let report = run(
                Rc::clone(&p),
                WorkloadConfig { requests: 40, rate_rps: 10.0, seed: 3, timeout_ms: 60_000.0 },
            )
            .await
            .unwrap();
            assert_eq!(report.issued, 40);
            assert_eq!(report.ok, 40);
            assert_eq!(report.failed, 0);
            // open loop: last arrival at 3.9s, so the run spans at least that
            assert!(report.duration_ms >= 3_900.0, "{}", report.duration_ms);
            assert!(report.latency.median() > 0.0);
            p.shutdown();
        });
    }

    #[test]
    fn targeted_run_hits_an_interior_function() {
        run_virtual(async {
            let cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled).vanilla();
            let p = crate::platform::Platform::deploy(apps::chain(3), cfg).await.unwrap();
            let report = run_targeted(
                Rc::clone(&p),
                WorkloadConfig { requests: 10, rate_rps: 10.0, seed: 4, timeout_ms: 60_000.0 },
                Arrival::Constant,
                Some("s2"),
            )
            .await
            .unwrap();
            assert_eq!(report.failed, 0);
            // s2 is the chain tail: only it executed, never s0/s1
            let fn_lat = p.metrics.fn_latency_series();
            assert!(fn_lat.iter().all(|s| s.function == "s2"), "{fn_lat:?}");
            assert_eq!(fn_lat.len(), 10);
            p.shutdown();
        });
    }

    #[test]
    fn traced_run_conserves_every_trace_and_never_perturbs_the_schedule() {
        run_virtual(async {
            let wl =
                WorkloadConfig { requests: 30, rate_rps: 20.0, seed: 5, timeout_ms: 60_000.0 };
            // untraced twin first: the baseline schedule
            let cfg0 = PlatformConfig::tiny().with_compute(ComputeMode::Disabled).vanilla();
            let p0 = crate::platform::Platform::deploy(apps::chain(3), cfg0).await.unwrap();
            let r0 = run(Rc::clone(&p0), wl.clone()).await.unwrap();
            p0.shutdown();

            let mut cfg = PlatformConfig::tiny().with_compute(ComputeMode::Disabled).vanilla();
            cfg.trace.sample_every = 1;
            cfg.trace.max_traces = 64;
            let p = crate::platform::Platform::deploy(apps::chain(3), cfg).await.unwrap();
            let report = run(Rc::clone(&p), wl).await.unwrap();
            assert_eq!(report.failed, 0);
            // every request retained (sample 1), every trace exact
            assert_eq!(p.tracer.conservation_violations(), 0);
            let traces = p.tracer.snapshot();
            assert_eq!(traces.len(), 30);
            for t in &traces {
                crate::trace::verify(t).unwrap_or_else(|e| panic!("{e}"));
                assert!(t.conserved);
            }
            // chain(3) vanilla: remote hops appear in the span taxonomy
            let csv = p.tracer.latency_breakdown_csv();
            assert!(csv.contains(",network,"), "{csv}");
            assert!(csv.contains(",dispatch,"), "{csv}");
            assert!(csv.contains(",self,"), "{csv}");
            // tracing is schedule-transparent: bit-identical latencies
            assert_eq!(
                report.latency.median().to_bits(),
                r0.latency.median().to_bits(),
                "tracing must not perturb the schedule"
            );
            assert_eq!(report.latency.mean().to_bits(), r0.latency.mean().to_bits());
            p.shutdown();
        });
    }

    #[test]
    fn summary_formats() {
        let r = WorkloadReport {
            issued: 10,
            ok: 9,
            failed: 1,
            latency: Quantiles::from_samples(vec![1.0, 2.0, 3.0]),
            duration_ms: 1000.0,
        };
        let s = r.summary();
        assert!(s.contains("9 ok"));
        assert!(s.contains("1 failed"));
    }

    #[test]
    fn merged_reports_pool_lanes_into_one_distribution() {
        let a = WorkloadReport {
            issued: 4,
            ok: 3,
            failed: 1,
            latency: Quantiles::from_samples(vec![3.0, 1.0, 5.0]),
            duration_ms: 900.0,
        };
        let b = WorkloadReport {
            issued: 6,
            ok: 6,
            failed: 0,
            latency: Quantiles::from_samples(vec![2.0, 4.0]),
            duration_ms: 1200.0,
        };
        let m = WorkloadReport::merged(&[a, b]);
        assert_eq!((m.issued, m.ok, m.failed), (10, 9, 1));
        assert_eq!(m.duration_ms, 1200.0);
        assert_eq!(m.latency.samples(), &[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(m.latency.median(), 3.0);
        let empty = WorkloadReport::merged(&[]);
        assert_eq!(empty.issued, 0);
        assert!(empty.latency.is_empty());
    }
}
