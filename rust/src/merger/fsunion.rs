//! Collision-preserving filesystem union (paper §3):
//!
//! > "To prevent file overwrites caused by colliding function names, the
//! > Merger preserves the original identifiers of each function instance
//! > while copying them into the shared file system."
//!
//! Shared platform layers (`/runtime/...`, `/platform/...`) with identical
//! digests are deduplicated; everything under `/app/` is kept per-function.
//! Genuine digest conflicts on a shared path are resolved by namespacing
//! the conflicting copy under `/merged/<tag>/...` so no input file is lost.

use crate::containerd::{FileEntry, FsManifest};

/// Union the filesystems of instances being merged.
/// `parts` = (instance tag, manifest) in merge order.
pub fn union_namespaced(parts: &[(String, FsManifest)]) -> FsManifest {
    let mut out: Vec<FileEntry> = Vec::new();

    for (tag, manifest) in parts {
        for entry in manifest.entries() {
            match out.iter().find(|e| e.path == entry.path) {
                None => out.push(entry.clone()),
                Some(existing) if existing.digest == entry.digest => {
                    // identical shared layer (runtime, handler shim): dedup
                }
                Some(_) => {
                    // same path, different contents: preserve under a
                    // namespaced copy instead of overwriting
                    out.push(FileEntry {
                        path: format!("/merged/{tag}{}", entry.path),
                        size_kb: entry.size_kb,
                        digest: entry.digest,
                    });
                }
            }
        }
    }
    FsManifest::new(out)
}

/// Check that every input file is reachable in the union — either at its
/// original path with the same digest, or under the `/merged/<tag>` prefix.
/// (The property the paper's collision-preservation rule guarantees; used
/// by tests and by the Merger's post-union assertion.)
pub fn union_preserves(parts: &[(String, FsManifest)], union: &FsManifest) -> bool {
    for (tag, manifest) in parts {
        for entry in manifest.entries() {
            let direct = union.get(&entry.path).map(|e| e.digest == entry.digest);
            let namespaced = union
                .get(&format!("/merged/{tag}{}", entry.path))
                .map(|e| e.digest == entry.digest);
            if direct != Some(true) && namespaced != Some(true) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(tag: &str, files: &[(&str, u64, u64)]) -> (String, FsManifest) {
        (
            tag.to_string(),
            FsManifest::new(
                files
                    .iter()
                    .map(|(p, s, d)| FileEntry {
                        path: p.to_string(),
                        size_kb: *s,
                        digest: *d,
                    })
                    .collect(),
            ),
        )
    }

    #[test]
    fn disjoint_functions_union_cleanly() {
        let a = part("i1", &[("/runtime/py", 100, 1), ("/app/a/main.py", 5, 10)]);
        let b = part("i2", &[("/runtime/py", 100, 1), ("/app/b/main.py", 7, 20)]);
        let u = union_namespaced(&[a.clone(), b.clone()]);
        assert_eq!(u.len(), 3); // runtime deduped
        assert!(u.contains_path("/app/a/main.py"));
        assert!(u.contains_path("/app/b/main.py"));
        assert!(union_preserves(&[a, b], &u));
    }

    #[test]
    fn colliding_paths_are_preserved_not_overwritten() {
        let a = part("i1", &[("/app/shared/config.json", 1, 111)]);
        let b = part("i2", &[("/app/shared/config.json", 1, 222)]);
        let u = union_namespaced(&[a.clone(), b.clone()]);
        assert_eq!(u.len(), 2);
        assert_eq!(u.get("/app/shared/config.json").unwrap().digest, 111);
        assert_eq!(u.get("/merged/i2/app/shared/config.json").unwrap().digest, 222);
        assert!(union_preserves(&[a, b], &u));
    }

    #[test]
    fn real_function_manifests_union() {
        let a = ("i1".to_string(), FsManifest::function_code("alpha", 50));
        let b = ("i2".to_string(), FsManifest::function_code("beta", 60));
        let u = union_namespaced(&[a.clone(), b.clone()]);
        // 2 shared layers + 2 files per function
        assert_eq!(u.len(), 6);
        assert!(union_preserves(&[a, b], &u));
    }

    #[test]
    fn union_is_idempotent_for_identical_parts() {
        let a = ("i1".to_string(), FsManifest::function_code("x", 10));
        let u = union_namespaced(&[a.clone(), a.clone()]);
        assert_eq!(u, a.1);
    }

    #[test]
    fn three_way_union_preserves_all() {
        let parts = vec![
            part("i1", &[("/app/f/cfg", 1, 1), ("/app/f/main.py", 2, 2)]),
            part("i2", &[("/app/f/cfg", 1, 3), ("/app/g/main.py", 2, 4)]),
            part("i3", &[("/app/f/cfg", 1, 5), ("/app/h/main.py", 2, 6)]),
        ];
        let u = union_namespaced(&parts);
        assert!(union_preserves(&parts, &u));
        assert!(u.contains_path("/merged/i2/app/f/cfg"));
        assert!(u.contains_path("/merged/i3/app/f/cfg"));
    }

    #[test]
    fn property_union_always_preserves() {
        crate::util::prop::check("fsunion preserves all inputs", 200, |g| {
            let n_parts = g.usize(1, 4);
            let parts: Vec<(String, FsManifest)> = (0..n_parts)
                .map(|i| {
                    let files = g.vec(12, |g| FileEntry {
                        // small path space to force collisions
                        path: format!("/app/{}/f{}", g.ident(2), g.usize(0, 3)),
                        size_kb: g.usize(1, 100) as u64,
                        digest: g.usize(0, 6) as u64,
                    });
                    (format!("i{i}"), FsManifest::new(files))
                })
                .collect();
            let u = union_namespaced(&parts);
            assert!(union_preserves(&parts, &u), "parts={parts:?}\nunion={u:?}");
        });
    }
}
