//! The Merger (paper §3): consolidates independently deployed function
//! instances into a single container — and, closing the feedback loop,
//! breaks regressing groups back apart (see [`split`]).
//!
//! Fuse pipeline per request: resolve replica sets → export filesystems →
//! collision-preserving union → build fused image → deploy one fused
//! replica per slot of the busier endpoint → health gate → atomic route
//! cutover to the fused set → drain every original replica → terminate.
//! Failures at any stage roll back (never-routed instances are torn down,
//! the pair goes on cooldown) and the platform keeps serving from the
//! originals.
//!
//! Split pipeline (defusion) per request: re-deploy the original
//! per-function instances from their retained images → health gate →
//! atomic route cutover back → drain and terminate the fused instance →
//! cooldown the pairs in the Observer so fuse ∧ split cannot flap.

pub mod fsunion;
pub mod split;

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::cluster::{Cluster, Migrator, NodeId, Scheduler};
use crate::config::PlatformConfig;
use crate::containerd::{ContainerRuntime, ImageId, Instance};
use crate::error::{Error, Result};
use crate::exec;
use crate::exec::channel::Receiver;
use crate::fusion::{admit_group, FusionRequest, Observer, Plan, PlanAction, SplitReason};
use crate::gateway::Gateway;
use crate::metrics::{MergeEvent, PlanEvent, Recorder};
use crate::platform::deployer::Deployer;
use crate::replica::ReplicaSet;

/// Everything the Merger needs from the platform.
pub struct MergerCtx {
    pub config: Rc<PlatformConfig>,
    pub containers: ContainerRuntime,
    pub cluster: Cluster,
    pub scheduler: Scheduler,
    pub gateway: Gateway,
    pub observer: Rc<Observer>,
    pub metrics: Recorder,
    pub deployer: Deployer,
    /// Retained single-function images from the initial deployment — the
    /// artifact sets the split pipeline re-deploys originals from.
    pub originals: Rc<BTreeMap<String, ImageId>>,
}

/// The Merger service: processes fusion requests sequentially (one merge in
/// flight at a time, matching the serialized merge events of paper Fig. 5).
pub struct Merger {
    ctx: MergerCtx,
}

impl Merger {
    pub fn new(ctx: MergerCtx) -> Self {
        Merger { ctx }
    }

    /// Service loop; ends when all request senders are dropped.
    pub async fn run(self, mut rx: Receiver<FusionRequest>) {
        while let Some(req) = rx.recv().await {
            self.process(req).await;
        }
    }

    /// Handle one request with failure feedback to the Observer.  The
    /// platform keeps serving from the pre-request topology on any error.
    pub async fn process(&self, req: FusionRequest) {
        match req {
            FusionRequest::Fuse { caller, callee } => {
                if let Err(err) = self.handle_fuse(&caller, &callee).await {
                    self.ctx.metrics.bump("fusion_aborted");
                    self.ctx.observer.fusion_failed(&caller, &callee);
                    let _ = err;
                }
            }
            FusionRequest::Split { functions, reason } => {
                if let Err(err) = self.handle_split(&functions, reason).await {
                    self.ctx.metrics.bump("split_aborted");
                    self.ctx.observer.split_failed(&functions);
                    let _ = err;
                }
            }
            FusionRequest::Evict { functions, function, reason } => {
                if let Err(err) = self.handle_evict(&functions, &function, reason).await {
                    self.ctx.metrics.bump("evict_aborted");
                    self.ctx.observer.evict_failed(&functions);
                    let _ = err;
                }
            }
            FusionRequest::Migrate { functions, to } => {
                match self.migrator().migrate(&functions, to, "node_pressure").await {
                    Ok(_) => self.ctx.observer.migrate_succeeded(&functions),
                    Err(err) => {
                        self.ctx.metrics.bump("migration_aborted");
                        self.ctx.observer.migrate_failed(&functions);
                        let _ = err;
                    }
                }
            }
            FusionRequest::Plan(plan) => self.execute_plan(plan).await,
        }
    }

    /// Execute a global re-planner plan-diff action by action through the
    /// existing pipelines, under the stale-topology abort guard.
    ///
    /// Every completed fuse/split/evict/migrate bumps the Observer's
    /// topology epoch exactly once, so the executor's expectation is
    /// `plan.epoch + completed_actions`.  Any disagreement — a topology
    /// change that raced the plan, or an action that failed or aborted —
    /// abandons the **remainder** cleanly: no partial re-application, and
    /// none of the greedy failure callbacks fire (a dropped plan must not
    /// poison pair cooldowns or node retry budgets; the next re-plan
    /// starts from a fresh snapshot instead).
    pub async fn execute_plan(&self, plan: Plan) {
        let ctx = &self.ctx;
        ctx.metrics.bump("plan_requests");
        let mut expected = plan.epoch;
        for (i, action) in plan.actions.iter().enumerate() {
            if ctx.observer.topology_epoch() != expected {
                self.plan_event(&plan, "aborted", format!("stale_epoch_before_action_{i}"));
                ctx.metrics.bump("plan_aborted_stale");
                return;
            }
            let result = match action {
                PlanAction::Split { functions } => {
                    self.handle_split(functions, SplitReason::CostModel).await
                }
                PlanAction::Evict { functions, function } => {
                    self.handle_evict(functions, function, SplitReason::CostModel).await
                }
                // plan fuses ride the full merge pipeline but bypass the
                // pair-cooldown anti-flap gate: the cooldowns set by this
                // plan's own splits must not veto its target partition
                PlanAction::Fuse { caller, callee } => {
                    self.fuse_inner(caller, callee, false).await
                }
                PlanAction::Migrate { functions, to } => self
                    .migrator()
                    .migrate(functions, *to, "plan")
                    .await
                    .map(|_| ctx.observer.migrate_succeeded(functions)),
            };
            if let Err(err) = result {
                self.plan_event(&plan, "aborted", format!("action_{i}_failed: {err}"));
                ctx.metrics.bump("plan_aborted_action");
                return;
            }
            let now_epoch = ctx.observer.topology_epoch();
            if now_epoch != expected + 1 {
                // the action completed without exactly one epoch bump — a
                // no-op cutover or an interleaved foreign change; either
                // way the plan no longer describes the live topology
                self.plan_event(&plan, "aborted", format!("epoch_skew_after_action_{i}"));
                ctx.metrics.bump("plan_aborted_stale");
                return;
            }
            expected = now_epoch;
        }
        self.plan_event(&plan, "executed", plan.summary());
        ctx.metrics.bump("plans_executed");
    }

    fn plan_event(&self, plan: &Plan, kind: &str, detail: String) {
        self.ctx.metrics.record_plan(PlanEvent {
            t_ms: self.ctx.metrics.rel_now_ms(),
            plan_id: plan.id,
            kind: kind.to_string(),
            actions: plan.actions.len() as u32,
            predicted_before: plan.predicted_before,
            predicted_after: plan.predicted_after,
            realized: f64::NAN,
            detail,
        });
    }

    /// Migration engine over this Merger's platform context (sharing the
    /// platform-flavored deployer, so a Kube migration pays the same
    /// reconcile-tick delay as every other pipeline's launch).
    pub fn migrator(&self) -> Migrator {
        Migrator::new(
            self.ctx.cluster.clone(),
            self.ctx.deployer.clone(),
            self.ctx.gateway.clone(),
            self.ctx.metrics.clone(),
            Rc::clone(&self.ctx.config),
        )
    }

    /// One merge. Public for targeted tests.
    pub async fn handle_fuse(&self, caller: &str, callee: &str) -> Result<()> {
        self.fuse_inner(caller, callee, true).await
    }

    /// The merge pipeline.  `respect_cooldown` is false only for plan-diff
    /// fuses, whose target partition already excluded cooling pairs at
    /// snapshot time — the cooldowns its own splits just set must not veto
    /// the remainder of the plan.
    async fn fuse_inner(&self, caller: &str, callee: &str, respect_cooldown: bool) -> Result<()> {
        let ctx = &self.ctx;
        ctx.metrics.bump("fusion_requests");

        // 1. resolve both endpoints to their *current* replica sets (either
        //    may already be a fused set -> transitive growth); sharing one
        //    set IS the "fused together" relation
        let set_a = ctx.gateway.resolve_set(caller)?;
        let set_b = ctx.gateway.resolve_set(callee)?;
        if Rc::ptr_eq(&set_a, &set_b) {
            ctx.metrics.bump("fusion_already_colocated");
            return Ok(());
        }
        let a = set_a.primary().ok_or_else(|| {
            Error::FusionAborted(format!("`{caller}` has no live replica"))
        })?;
        let b = set_b.primary().ok_or_else(|| {
            Error::FusionAborted(format!("`{callee}` has no live replica"))
        })?;
        let policy = ctx.observer.policy();
        if !policy.transitive && (a.fn_count() > 1 || b.fn_count() > 1) {
            return Err(Error::FusionAborted("transitive growth disabled".into()));
        }
        admit_group(policy, a.fn_count() + b.fn_count())?;
        // Anti-flap: the observed pair was cooldown-checked at admission,
        // but either endpoint may meanwhile be fused with third parties —
        // a transitive merge must not reunite ANY pair a recent defusion
        // put on cooldown before that cooldown expires.
        if respect_cooldown {
            for (x, _) in a.functions() {
                for (y, _) in b.functions() {
                    if ctx.observer.pair_in_cooldown(&x, &y)
                        || ctx.observer.pair_in_cooldown(&y, &x)
                    {
                        return Err(Error::FusionAborted(format!(
                            "pair ({x}, {y}) is cooling down after a defusion"
                        )));
                    }
                }
            }
        }

        let t_start = exec::now();

        // 2. co-location precondition: an inline call needs a shared
        //    process, which first needs a shared node.  When any callee
        //    replica lives apart, migrate the callee's set to the caller's
        //    node before any image work — the cost planner already priced
        //    this move (`MergeContext::migration_ms`) and capacity-gated
        //    it, and the migrator re-checks capacity regardless (the
        //    observation-count policy has no planner to do it for it).
        let target_node = ctx.cluster.node_of(a.id()).unwrap_or(NodeId(0));
        let b = if set_b
            .live()
            .iter()
            .any(|i| matches!(ctx.cluster.node_of(i.id()), Some(n) if n != target_node))
        {
            let fns: Vec<String> =
                b.functions().iter().map(|(n, _)| n.clone()).collect();
            self.migrator().migrate(&fns, target_node, "fusion_colocation").await?;
            ctx.metrics.bump("fusion_colocation_migrations");
            // the set was rewritten in place; re-sample a live replica
            set_b.primary().ok_or_else(|| {
                Error::FusionAborted(format!(
                    "`{callee}` lost its replicas during co-location"
                ))
            })?
        } else {
            b
        };

        // 3. export + union filesystems (collision-preserving)
        let fs_a = ctx.containers.export_fs(&a)?;
        let fs_b = ctx.containers.export_fs(&b)?;
        let parts = vec![(a.id().to_string(), fs_a), (b.id().to_string(), fs_b)];
        let merged = fsunion::union_namespaced(&parts);
        debug_assert!(fsunion::union_preserves(&parts, &merged));

        // 4. build the fused image (charged build latency; may fail)
        let mut functions = a.functions();
        functions.extend(b.functions());
        let image = ctx.containers.build_image(merged, functions.clone()).await?;

        // 5. deploy on the caller's node (platform-flavored: direct or
        //    reconciler-gated) — the fused set inherits the placement the
        //    co-location step just established, at the replica count of the
        //    busier endpoint (fusing a 4-replica caller with a 1-replica
        //    callee must not shrink the caller's capacity)
        let replica_count = set_a.live_len().max(set_b.live_len()).max(1);
        let mut fused_replicas: Vec<Rc<Instance>> = Vec::with_capacity(replica_count);
        for _ in 0..replica_count {
            match ctx.deployer.launch(image, target_node).await {
                Ok(inst) => fused_replicas.push(inst),
                Err(err) => {
                    self.teardown(&fused_replicas);
                    return Err(err);
                }
            }
        }

        // 6. health gate: N consecutive successes on EVERY replica before
        //    any traffic cutover (boots overlap; the waits are sequential)
        for inst in &fused_replicas {
            if let Err(err) = self.await_healthy(inst).await {
                ctx.metrics.bump("fusion_health_timeouts");
                // roll back the never-routed replicas
                self.teardown(&fused_replicas);
                return Err(err);
            }
        }

        // 7. capture the pre-fusion latency regime for the feedback
        //    controller, then atomically swap routes for every hosted
        //    function.  A trailing window (not all-time) keeps the baseline
        //    anchored to the regime right before this cutover, so re-fusions
        //    after a split aren't judged against stale history.
        let baseline_p95_ms = {
            let now_ms = ctx.metrics.rel_now_ms();
            let lookback = ctx.observer.policy().baseline_lookback_ms();
            ctx.metrics.p95_window(
                (now_ms - lookback).max(0.0),
                now_ms,
                crate::metrics::MIN_WINDOW_SAMPLES,
            )
        };
        let names: Vec<String> = functions.iter().map(|(n, _)| n.clone()).collect();
        let fused = ReplicaSet::new(fused_replicas, image);
        ctx.gateway.swap_routes_set(&names, Rc::clone(&fused))?;
        let now = exec::now();
        ctx.metrics.record_merge(MergeEvent {
            t_ms: ctx.metrics.rel_now_ms(),
            functions: names.clone(),
            duration_ms: now.duration_since(t_start).as_secs_f64() * 1e3,
        });
        ctx.metrics.bump("fusions_completed");
        ctx.observer.fusion_succeeded(caller, callee, &names, baseline_p95_ms);

        // 8. drain + terminate every original replica of both endpoints off
        //    the merge loop ("stopped and deleted as soon as they are no
        //    longer processing requests").  Retire the old sets first so a
        //    scale-up that raced this cutover cannot attach a fresh replica
        //    to either of them.
        set_a.retire();
        set_b.retire();
        for old in set_a.live().into_iter().chain(set_b.live()) {
            old.begin_drain()?;
            self.reclaim_when_drained(old);
        }
        Ok(())
    }

    /// Tear down never-routed replicas after a mid-pipeline failure.
    fn teardown(&self, never_routed: &[Rc<Instance>]) {
        for inst in never_routed {
            let _ = inst.begin_drain();
            let _ = self.ctx.containers.terminate(inst);
        }
    }

    /// Terminate `old` once its in-flight requests have drained (detached;
    /// delegates to the shared pipeline tail in [`crate::containerd`]).
    pub(crate) fn reclaim_when_drained(&self, old: Rc<Instance>) {
        crate::containerd::reclaim_when_drained(
            self.ctx.containers.clone(),
            self.ctx.metrics.clone(),
            old,
        );
    }

    /// The shared pre-cutover health gate (see
    /// [`crate::containerd::await_healthy`]).
    pub(crate) async fn await_healthy(&self, inst: &Rc<Instance>) -> Result<()> {
        crate::containerd::await_healthy(&self.ctx.config.latency, inst).await
    }
}
