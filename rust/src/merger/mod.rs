//! The Merger (paper §3): consolidates independently deployed function
//! instances into a single container.
//!
//! Pipeline per fusion request: resolve instances → export filesystems →
//! collision-preserving union → build fused image → deploy → health gate →
//! atomic route cutover → drain originals → terminate.  Failures at any
//! stage roll back (never-routed instances are torn down, the pair goes on
//! cooldown) and the platform keeps serving from the originals.

pub mod fsunion;

use std::rc::Rc;

use crate::config::PlatformConfig;
use crate::containerd::{ContainerRuntime, Instance};
use crate::error::{Error, Result};
use crate::exec;
use crate::exec::channel::Receiver;
use crate::fusion::{admit_group, FusionRequest, Observer};
use crate::gateway::Gateway;
use crate::metrics::{MergeEvent, Recorder};
use crate::platform::deployer::Deployer;

/// Everything the Merger needs from the platform.
pub struct MergerCtx {
    pub config: Rc<PlatformConfig>,
    pub containers: ContainerRuntime,
    pub gateway: Gateway,
    pub observer: Rc<Observer>,
    pub metrics: Recorder,
    pub deployer: Deployer,
}

/// The Merger service: processes fusion requests sequentially (one merge in
/// flight at a time, matching the serialized merge events of paper Fig. 5).
pub struct Merger {
    ctx: MergerCtx,
}

impl Merger {
    pub fn new(ctx: MergerCtx) -> Self {
        Merger { ctx }
    }

    /// Service loop; ends when all request senders are dropped.
    pub async fn run(self, mut rx: Receiver<FusionRequest>) {
        while let Some(req) = rx.recv().await {
            if let Err(err) = self.handle(&req).await {
                self.ctx.metrics.bump("fusion_aborted");
                self.ctx.observer.fusion_failed(&req.caller, &req.callee);
                // The platform keeps serving from the original instances.
                let _ = err;
            }
        }
    }

    /// One merge. Public for targeted tests.
    pub async fn handle(&self, req: &FusionRequest) -> Result<()> {
        let ctx = &self.ctx;
        ctx.metrics.bump("fusion_requests");

        // 1. resolve both endpoints to their *current* instances (either may
        //    already be a fused instance -> transitive growth)
        let a = ctx.gateway.resolve(&req.caller)?;
        let b = ctx.gateway.resolve(&req.callee)?;
        if a.id() == b.id() {
            ctx.metrics.bump("fusion_already_colocated");
            return Ok(());
        }
        let policy = ctx.observer.policy();
        if !policy.transitive && (a.functions().len() > 1 || b.functions().len() > 1) {
            return Err(Error::FusionAborted("transitive growth disabled".into()));
        }
        let group_size = a.functions().len() + b.functions().len();
        admit_group(policy, group_size)?;

        let t_start = exec::now();

        // 2. export + union filesystems (collision-preserving)
        let fs_a = ctx.containers.export_fs(&a)?;
        let fs_b = ctx.containers.export_fs(&b)?;
        let parts = vec![(a.id().to_string(), fs_a), (b.id().to_string(), fs_b)];
        let merged = fsunion::union_namespaced(&parts);
        debug_assert!(fsunion::union_preserves(&parts, &merged));

        // 3. build the fused image (charged build latency; may fail)
        let mut functions = a.functions().to_vec();
        functions.extend(b.functions().iter().cloned());
        let image = ctx.containers.build_image(merged, functions.clone()).await?;

        // 4. deploy (platform-flavored: direct or reconciler-gated)
        let fused = ctx.deployer.launch(image).await?;

        // 5. health gate: N consecutive successes before any traffic cutover
        self.await_healthy(&fused).await.inspect_err(|_| {
            // roll back the never-routed instance
            let _ = fused.begin_drain();
            let _ = ctx.containers.terminate(&fused);
        })?;

        // 6. atomic route cutover for every hosted function
        let names: Vec<String> = functions.iter().map(|(n, _)| n.clone()).collect();
        ctx.gateway.swap_routes(&names, Rc::clone(&fused))?;
        let now = exec::now();
        ctx.metrics.record_merge(MergeEvent {
            t_ms: ctx.metrics.rel_now_ms(),
            functions: names,
            duration_ms: now.duration_since(t_start).as_secs_f64() * 1e3,
        });
        ctx.metrics.bump("fusions_completed");
        ctx.observer.fusion_succeeded(&req.caller, &req.callee);

        // 7. drain + terminate the originals off the merge loop ("stopped
        //    and deleted as soon as they are no longer processing requests")
        for old in [a, b] {
            old.begin_drain()?;
            let containers = ctx.containers.clone();
            let metrics = ctx.metrics.clone();
            exec::spawn(async move {
                old.drained().await;
                if containers.terminate(&old).is_ok() {
                    metrics.bump("instances_reclaimed");
                }
            });
        }
        Ok(())
    }

    /// Poll health checks until `health_checks_required` consecutive passes
    /// or the deadline (4x boot + 5s) expires.
    async fn await_healthy(&self, inst: &Rc<Instance>) -> Result<()> {
        let lat = &self.ctx.config.latency;
        let deadline_ms =
            exec::now().as_millis_f64() + lat.boot_ms * 4.0 + 5_000.0;
        let mut passes = 0u32;
        loop {
            exec::sleep_ms(lat.health_interval_ms).await;
            if self.ctx.containers.health_check(inst) {
                passes += 1;
                if passes >= lat.health_checks_required {
                    return Ok(());
                }
            } else {
                passes = 0;
            }
            if exec::now().as_millis_f64() > deadline_ms {
                self.ctx.metrics.bump("fusion_health_timeouts");
                return Err(Error::HealthTimeout(inst.id().0));
            }
        }
    }
}
