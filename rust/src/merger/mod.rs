//! The Merger (paper §3): consolidates independently deployed function
//! instances into a single container — and, closing the feedback loop,
//! breaks regressing groups back apart (see [`split`]).
//!
//! Fuse pipeline per request: resolve instances → export filesystems →
//! collision-preserving union → build fused image → deploy → health gate →
//! atomic route cutover → drain originals → terminate.  Failures at any
//! stage roll back (never-routed instances are torn down, the pair goes on
//! cooldown) and the platform keeps serving from the originals.
//!
//! Split pipeline (defusion) per request: re-deploy the original
//! per-function instances from their retained images → health gate →
//! atomic route cutover back → drain and terminate the fused instance →
//! cooldown the pairs in the Observer so fuse ∧ split cannot flap.

pub mod fsunion;
pub mod split;

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::config::PlatformConfig;
use crate::containerd::{ContainerRuntime, ImageId, Instance};
use crate::error::{Error, Result};
use crate::exec;
use crate::exec::channel::Receiver;
use crate::fusion::{admit_group, FusionRequest, Observer};
use crate::gateway::Gateway;
use crate::metrics::{MergeEvent, Recorder};
use crate::platform::deployer::Deployer;

/// Everything the Merger needs from the platform.
pub struct MergerCtx {
    pub config: Rc<PlatformConfig>,
    pub containers: ContainerRuntime,
    pub gateway: Gateway,
    pub observer: Rc<Observer>,
    pub metrics: Recorder,
    pub deployer: Deployer,
    /// Retained single-function images from the initial deployment — the
    /// artifact sets the split pipeline re-deploys originals from.
    pub originals: Rc<BTreeMap<String, ImageId>>,
}

/// The Merger service: processes fusion requests sequentially (one merge in
/// flight at a time, matching the serialized merge events of paper Fig. 5).
pub struct Merger {
    ctx: MergerCtx,
}

impl Merger {
    pub fn new(ctx: MergerCtx) -> Self {
        Merger { ctx }
    }

    /// Service loop; ends when all request senders are dropped.
    pub async fn run(self, mut rx: Receiver<FusionRequest>) {
        while let Some(req) = rx.recv().await {
            self.process(req).await;
        }
    }

    /// Handle one request with failure feedback to the Observer.  The
    /// platform keeps serving from the pre-request topology on any error.
    pub async fn process(&self, req: FusionRequest) {
        match req {
            FusionRequest::Fuse { caller, callee } => {
                if let Err(err) = self.handle_fuse(&caller, &callee).await {
                    self.ctx.metrics.bump("fusion_aborted");
                    self.ctx.observer.fusion_failed(&caller, &callee);
                    let _ = err;
                }
            }
            FusionRequest::Split { functions, reason } => {
                if let Err(err) = self.handle_split(&functions, reason).await {
                    self.ctx.metrics.bump("split_aborted");
                    self.ctx.observer.split_failed(&functions);
                    let _ = err;
                }
            }
            FusionRequest::Evict { functions, function, reason } => {
                if let Err(err) = self.handle_evict(&functions, &function, reason).await {
                    self.ctx.metrics.bump("evict_aborted");
                    self.ctx.observer.evict_failed(&functions);
                    let _ = err;
                }
            }
        }
    }

    /// One merge. Public for targeted tests.
    pub async fn handle_fuse(&self, caller: &str, callee: &str) -> Result<()> {
        let ctx = &self.ctx;
        ctx.metrics.bump("fusion_requests");

        // 1. resolve both endpoints to their *current* instances (either may
        //    already be a fused instance -> transitive growth)
        let a = ctx.gateway.resolve(caller)?;
        let b = ctx.gateway.resolve(callee)?;
        if a.id() == b.id() {
            ctx.metrics.bump("fusion_already_colocated");
            return Ok(());
        }
        let policy = ctx.observer.policy();
        if !policy.transitive && (a.fn_count() > 1 || b.fn_count() > 1) {
            return Err(Error::FusionAborted("transitive growth disabled".into()));
        }
        admit_group(policy, a.fn_count() + b.fn_count())?;
        // Anti-flap: the observed pair was cooldown-checked at admission,
        // but either endpoint may meanwhile be fused with third parties —
        // a transitive merge must not reunite ANY pair a recent defusion
        // put on cooldown before that cooldown expires.
        for (x, _) in a.functions() {
            for (y, _) in b.functions() {
                if ctx.observer.pair_in_cooldown(&x, &y)
                    || ctx.observer.pair_in_cooldown(&y, &x)
                {
                    return Err(Error::FusionAborted(format!(
                        "pair ({x}, {y}) is cooling down after a defusion"
                    )));
                }
            }
        }

        let t_start = exec::now();

        // 2. export + union filesystems (collision-preserving)
        let fs_a = ctx.containers.export_fs(&a)?;
        let fs_b = ctx.containers.export_fs(&b)?;
        let parts = vec![(a.id().to_string(), fs_a), (b.id().to_string(), fs_b)];
        let merged = fsunion::union_namespaced(&parts);
        debug_assert!(fsunion::union_preserves(&parts, &merged));

        // 3. build the fused image (charged build latency; may fail)
        let mut functions = a.functions();
        functions.extend(b.functions());
        let image = ctx.containers.build_image(merged, functions.clone()).await?;

        // 4. deploy (platform-flavored: direct or reconciler-gated)
        let fused = ctx.deployer.launch(image).await?;

        // 5. health gate: N consecutive successes before any traffic cutover
        self.await_healthy(&fused).await.inspect_err(|_| {
            ctx.metrics.bump("fusion_health_timeouts");
            // roll back the never-routed instance
            let _ = fused.begin_drain();
            let _ = ctx.containers.terminate(&fused);
        })?;

        // 6. capture the pre-fusion latency regime for the feedback
        //    controller, then atomically swap routes for every hosted
        //    function.  A trailing window (not all-time) keeps the baseline
        //    anchored to the regime right before this cutover, so re-fusions
        //    after a split aren't judged against stale history.
        let baseline_p95_ms = {
            let now_ms = ctx.metrics.rel_now_ms();
            let lookback = (ctx.observer.policy().feedback_interval_ms * 10.0).max(10_000.0);
            ctx.metrics.p95_window(
                (now_ms - lookback).max(0.0),
                now_ms,
                crate::metrics::MIN_WINDOW_SAMPLES,
            )
        };
        let names: Vec<String> = functions.iter().map(|(n, _)| n.clone()).collect();
        ctx.gateway.swap_routes(&names, Rc::clone(&fused))?;
        let now = exec::now();
        ctx.metrics.record_merge(MergeEvent {
            t_ms: ctx.metrics.rel_now_ms(),
            functions: names.clone(),
            duration_ms: now.duration_since(t_start).as_secs_f64() * 1e3,
        });
        ctx.metrics.bump("fusions_completed");
        ctx.observer.fusion_succeeded(caller, callee, &names, baseline_p95_ms);

        // 7. drain + terminate the originals off the merge loop ("stopped
        //    and deleted as soon as they are no longer processing requests")
        for old in [a, b] {
            old.begin_drain()?;
            self.reclaim_when_drained(old);
        }
        Ok(())
    }

    /// Terminate `old` once its in-flight requests have drained (detached).
    pub(crate) fn reclaim_when_drained(&self, old: Rc<Instance>) {
        let containers = self.ctx.containers.clone();
        let metrics = self.ctx.metrics.clone();
        exec::spawn(async move {
            old.drained().await;
            if containers.terminate(&old).is_ok() {
                metrics.bump("instances_reclaimed");
            }
        });
    }

    /// Poll health checks until `health_checks_required` consecutive passes
    /// or the deadline (4x boot + 5s) expires.
    pub(crate) async fn await_healthy(&self, inst: &Rc<Instance>) -> Result<()> {
        let lat = &self.ctx.config.latency;
        let deadline_ms =
            exec::now().as_millis_f64() + lat.boot_ms * 4.0 + 5_000.0;
        let mut passes = 0u32;
        loop {
            exec::sleep_ms(lat.health_interval_ms).await;
            if self.ctx.containers.health_check(inst) {
                passes += 1;
                if passes >= lat.health_checks_required {
                    return Ok(());
                }
            } else {
                passes = 0;
            }
            if exec::now().as_millis_f64() > deadline_ms {
                return Err(Error::HealthTimeout(inst.id().0));
            }
        }
    }
}
