//! Defusion: the reverse Merger pipeline (feedback-driven splitting).
//!
//! When the controller decides a fused group regressed — RAM over
//! `max_group_ram_mb` or p95 past the hysteresis threshold — the Merger
//! re-deploys the group's functions from their **retained original
//! images** (no image build: the initial per-function artifacts were never
//! discarded), health-gates every replacement, atomically cuts the routes
//! back over, and drains + terminates the fused instance.
//!
//! Failure at any stage rolls back: the never-routed replacements are torn
//! down, the fused instance keeps serving, and the group re-enters cooldown
//! (`Observer::split_failed`), so a flaky split can never drop a request.

use std::rc::Rc;

use crate::containerd::Instance;
use crate::error::{Error, Result};
use crate::exec;
use crate::fusion::SplitReason;
use crate::metrics::SplitEvent;

use super::Merger;

impl Merger {
    /// One split. Public for targeted tests.
    ///
    /// `functions` is the sorted function set the controller sampled; the
    /// split is aborted as stale when the live topology no longer matches
    /// (e.g. a racing transitive merge grew the group in the meantime).
    pub async fn handle_split(&self, functions: &[String], reason: SplitReason) -> Result<()> {
        let ctx = &self.ctx;
        ctx.metrics.bump("split_requests");

        if functions.len() < 2 {
            return Err(Error::SplitAborted("group has fewer than two functions".into()));
        }

        // 1. resolve the fused instance and check the sampled membership is
        //    still the live topology
        let fused = ctx.gateway.resolve(&functions[0])?;
        let mut hosted: Vec<String> =
            fused.functions().iter().map(|(n, _)| n.clone()).collect();
        hosted.sort();
        let mut expected: Vec<String> = functions.to_vec();
        expected.sort();
        if hosted != expected {
            return Err(Error::SplitAborted(format!(
                "stale group: sampled [{}] but instance {} hosts [{}]",
                expected.join("+"),
                fused.id(),
                hosted.join("+")
            )));
        }
        for f in &expected {
            if ctx.gateway.resolve(f)?.id() != fused.id() {
                return Err(Error::SplitAborted(format!(
                    "stale group: `{f}` no longer routed to instance {}",
                    fused.id()
                )));
            }
        }

        let t_start = exec::now();

        // 2. re-deploy one instance per function from its retained original
        //    image, then health-gate all of them before any traffic moves
        let fresh = self.deploy_originals(&expected).await?;

        // 3. atomic cutover: every function back to its own instance
        let routes: Vec<(String, Rc<Instance>)> = expected
            .iter()
            .cloned()
            .zip(fresh.iter().map(Rc::clone))
            .collect();
        ctx.gateway.swap_routes_multi(&routes).inspect_err(|_| self.rollback(&fresh))?;

        let now = exec::now();
        ctx.metrics.record_split(SplitEvent {
            t_ms: ctx.metrics.rel_now_ms(),
            functions: expected.clone(),
            duration_ms: now.duration_since(t_start).as_secs_f64() * 1e3,
            reason,
        });
        ctx.metrics.bump("splits_completed");
        ctx.observer.split_succeeded(&expected);

        // 4. drain + terminate the fused instance off the merge loop
        fused.begin_drain()?;
        self.reclaim_when_drained(fused);
        Ok(())
    }

    /// Launch a replacement instance per function and wait until every one
    /// is healthy.  Any failure tears down all replacements and bubbles the
    /// error (the fused instance was never un-routed, so it keeps serving).
    async fn deploy_originals(&self, functions: &[String]) -> Result<Vec<Rc<Instance>>> {
        let ctx = &self.ctx;
        let mut fresh: Vec<Rc<Instance>> = Vec::with_capacity(functions.len());
        for f in functions {
            let image = match ctx.originals.get(f) {
                Some(id) => *id,
                None => {
                    self.rollback(&fresh);
                    return Err(Error::SplitAborted(format!(
                        "no retained original image for `{f}`"
                    )));
                }
            };
            match ctx.deployer.launch(image).await {
                Ok(inst) => fresh.push(inst),
                Err(err) => {
                    self.rollback(&fresh);
                    return Err(err);
                }
            }
        }
        for inst in &fresh {
            if let Err(err) = self.await_healthy(inst).await {
                ctx.metrics.bump("split_health_timeouts");
                self.rollback(&fresh);
                return Err(err);
            }
        }
        Ok(fresh)
    }

    /// Tear down never-routed replacement instances.
    fn rollback(&self, fresh: &[Rc<Instance>]) {
        for inst in fresh {
            let _ = inst.begin_drain();
            let _ = self.ctx.containers.terminate(inst);
        }
    }
}
