//! Defusion: the reverse Merger pipeline (feedback-driven splitting).
//!
//! When the controller decides a fused group regressed — RAM over
//! `max_group_ram_mb` or p95 past the hysteresis threshold — the Merger
//! re-deploys the group's functions from their **retained original
//! images** (no image build: the initial per-function artifacts were never
//! discarded) at the fused set's replica count, health-gates every
//! replacement, atomically cuts the routes back over, and drains +
//! terminates every fused replica.
//!
//! Failure at any stage rolls back: the never-routed replacements are torn
//! down, the fused set keeps serving, and the group re-enters cooldown
//! (`Observer::split_failed`), so a flaky split can never drop a request.
//!
//! The **partial-split** pipeline ([`Merger::handle_evict`]) is the same
//! machinery scoped to one member: redeploy only the evicted function's
//! original image, health-gate it, atomically re-route just its edges, and
//! shrink every fused replica in place (the remainder keeps serving and
//! never stops).  Only the evicted pairs enter cooldown.

use std::rc::Rc;

use crate::cluster::NodeId;
use crate::containerd::{ImageId, Instance};
use crate::error::{Error, Result};
use crate::exec;
use crate::fusion::SplitReason;
use crate::metrics::{EvictEvent, SplitEvent};
use crate::replica::ReplicaSet;

use super::Merger;

impl Merger {
    /// Resolve the live fused replica set hosting the sampled `functions`
    /// and verify the sampled membership is still the live topology: the
    /// set's active function set equals the (sorted) sample and every
    /// member still routes to the same set.  Shared staleness gate of the
    /// split and evict pipelines; returns
    /// `(fused set, a live replica of it, sorted membership)`.
    fn resolve_live_group(
        &self,
        functions: &[String],
    ) -> Result<(Rc<ReplicaSet>, Rc<Instance>, Vec<String>)> {
        if functions.len() < 2 {
            return Err(Error::SplitAborted("group has fewer than two functions".into()));
        }
        let set = self.ctx.gateway.resolve_set(&functions[0])?;
        let fused = set.primary().ok_or_else(|| {
            Error::SplitAborted(format!(
                "stale group: `{}` has no live replica",
                functions[0]
            ))
        })?;
        let mut hosted: Vec<String> =
            fused.functions().iter().map(|(n, _)| n.clone()).collect();
        hosted.sort();
        let mut expected: Vec<String> = functions.to_vec();
        expected.sort();
        if hosted != expected {
            return Err(Error::SplitAborted(format!(
                "stale group: sampled [{}] but instance {} hosts [{}]",
                expected.join("+"),
                fused.id(),
                hosted.join("+")
            )));
        }
        for f in &expected {
            if !Rc::ptr_eq(&self.ctx.gateway.resolve_set(f)?, &set) {
                return Err(Error::SplitAborted(format!(
                    "stale group: `{f}` no longer routed with `{}`",
                    expected[0]
                )));
            }
        }
        Ok((set, fused, expected))
    }

    /// One split. Public for targeted tests.
    ///
    /// `functions` is the sorted function set the controller sampled; the
    /// split is aborted as stale when the live topology no longer matches
    /// (e.g. a racing transitive merge grew the group in the meantime).
    pub async fn handle_split(&self, functions: &[String], reason: SplitReason) -> Result<()> {
        let ctx = &self.ctx;
        ctx.metrics.bump("split_requests");

        // 1. resolve the fused replica set and check the sampled membership
        //    is still the live topology
        let (fused_set, fused, expected) = self.resolve_live_group(functions)?;

        let t_start = exec::now();

        // 2. re-deploy one replica set per function from its retained
        //    original image — at the fused set's replica count, so a split
        //    never shrinks serving capacity — then health-gate every
        //    replacement before any traffic moves.  Replacements stay on
        //    the group's home node (single-node semantics preserved) —
        //    except a node-pressure split, whose entire point is shedding
        //    that node, so each replacement goes wherever the scheduler
        //    finds headroom.
        let home = self.ctx.cluster.node_of(fused.id());
        let replica_count = fused_set.live_len().max(1);
        let fresh = self.deploy_originals(&expected, reason, home, replica_count).await?;

        // 3. atomic cutover: every function back to its own replica set
        let routes: Vec<(String, Rc<ReplicaSet>)> = expected
            .iter()
            .cloned()
            .zip(fresh.iter().map(Rc::clone))
            .collect();
        ctx.gateway
            .swap_routes_multi_sets(&routes)
            .inspect_err(|_| self.rollback_sets(&fresh))?;

        let now = exec::now();
        ctx.metrics.record_split(SplitEvent {
            t_ms: ctx.metrics.rel_now_ms(),
            functions: expected.clone(),
            duration_ms: now.duration_since(t_start).as_secs_f64() * 1e3,
            reason,
        });
        ctx.metrics.bump("splits_completed");
        ctx.observer.split_succeeded(&expected);

        // 4. drain + terminate every fused replica off the merge loop
        //    (retired first, so a racing scale-up cannot grow the dead set)
        fused_set.retire();
        for old in fused_set.live() {
            old.begin_drain()?;
            self.reclaim_when_drained(old);
        }
        Ok(())
    }

    /// One partial split. Public for targeted tests.
    ///
    /// `functions` is the sorted group the controller sampled and
    /// `function` the member it chose to shed.  Stale topology (a racing
    /// transitive merge, a function already re-routed) aborts before any
    /// resource is committed; a failed redeploy rolls back with the fused
    /// instance untouched, so the group is restored intact and no request
    /// is ever dropped.
    pub async fn handle_evict(
        &self,
        functions: &[String],
        function: &str,
        reason: SplitReason,
    ) -> Result<()> {
        let ctx = &self.ctx;
        ctx.metrics.bump("evict_requests");

        if !functions.iter().any(|f| f == function) {
            return Err(Error::SplitAborted(format!(
                "`{function}` is not a member of [{}]",
                functions.join("+")
            )));
        }

        // 1. resolve the fused replica set and check the sampled membership
        //    is still the live topology
        let (fused_set, fused, expected) = self.resolve_live_group(functions)?;

        let t_start = exec::now();

        // 2. redeploy only the evicted function from its retained original
        //    image — at the fused set's replica count — and health-gate the
        //    replacements before any traffic moves
        let image = match ctx.originals.get(function) {
            Some(id) => *id,
            None => {
                return Err(Error::SplitAborted(format!(
                    "no retained original image for `{function}`"
                )))
            }
        };
        // the evicted member returns to its own replica set on the group's
        // home node (the defusion objective already priced its RAM there;
        // rebalancing across nodes is the pressure controller's job)
        let home = ctx.cluster.node_of(fused.id()).unwrap_or(NodeId(0));
        let replica_count = fused_set.live_len().max(1);
        let mut replicas: Vec<Rc<Instance>> = Vec::with_capacity(replica_count);
        for _ in 0..replica_count {
            match ctx.deployer.launch(image, home).await {
                Ok(inst) => replicas.push(inst),
                Err(err) => {
                    self.rollback(&replicas);
                    return Err(err);
                }
            }
        }
        for inst in &replicas {
            if let Err(err) = self.await_healthy(inst).await {
                ctx.metrics.bump("evict_health_timeouts");
                self.rollback(&replicas);
                return Err(err);
            }
        }
        let fresh = ReplicaSet::new(replicas, image);

        // 3. the launch + health gate awaited: re-check the topology so a
        //    racing pipeline cannot have invalidated the plan while we
        //    waited (nothing is committed yet — abort tears down only the
        //    never-routed replacements)
        for f in &expected {
            let routed = match ctx.gateway.resolve_set(f) {
                Ok(routed) => routed,
                Err(err) => {
                    self.rollback(&fresh.live());
                    return Err(err);
                }
            };
            if !Rc::ptr_eq(&routed, &fused_set) {
                self.rollback(&fresh.live());
                return Err(Error::SplitAborted(format!(
                    "group changed during redeploy: `{f}` moved off its \
                     replica set"
                )));
            }
        }
        if !fused_set.live().iter().all(|i| i.hosts(function)) {
            self.rollback(&fresh.live());
            return Err(Error::SplitAborted(format!(
                "group changed during redeploy: the fused set no longer \
                 hosts `{function}`"
            )));
        }

        // 4. atomic cutover of just the evicted function's route
        ctx.gateway
            .swap_routes_multi_sets(&[(function.to_string(), Rc::clone(&fresh))])
            .inspect_err(|_| self.rollback(&fresh.live()))?;

        // 5. shrink every fused replica in place: each keeps serving the
        //    remaining members and unloads the evicted function's code (its
        //    in-flight requests finish on the old replicas — zero drops).
        //    Should a shrink fail despite the re-check above, undo the
        //    cutover so the topology never ends with two active hosts.
        for old in fused_set.live() {
            if let Err(err) = old.evict_function(function) {
                let _ = ctx
                    .gateway
                    .swap_routes_multi_sets(&[(function.to_string(), Rc::clone(&fused_set))]);
                self.rollback(&fresh.live());
                return Err(err);
            }
        }

        ctx.metrics.record_evict(EvictEvent {
            t_ms: ctx.metrics.rel_now_ms(),
            group: expected.clone(),
            function: function.to_string(),
            duration_ms: exec::now().duration_since(t_start).as_secs_f64() * 1e3,
            reason,
        });
        ctx.metrics.bump("evictions_completed");
        ctx.observer.evict_succeeded(&expected, function);
        Ok(())
    }

    /// Launch a replacement replica set per function (each at
    /// `replica_count` replicas) and wait until every replica is healthy.
    /// Any failure tears down all replacements and bubbles the error (the
    /// fused set was never un-routed, so it keeps serving).
    async fn deploy_originals(
        &self,
        functions: &[String],
        reason: SplitReason,
        home: Option<NodeId>,
        replica_count: usize,
    ) -> Result<Vec<Rc<ReplicaSet>>> {
        let ctx = &self.ctx;
        let mut launched: Vec<Rc<Instance>> = Vec::new();
        let mut fresh: Vec<Rc<ReplicaSet>> = Vec::with_capacity(functions.len());
        for f in functions {
            let image = match ctx.originals.get(f) {
                Some(id) => *id,
                None => {
                    self.rollback(&launched);
                    return Err(Error::SplitAborted(format!(
                        "no retained original image for `{f}`"
                    )));
                }
            };
            let mut replicas: Vec<Rc<Instance>> = Vec::with_capacity(replica_count);
            for _ in 0..replica_count {
                let node = match self.replacement_node(image, reason, home) {
                    Ok(node) => node,
                    Err(err) => {
                        self.rollback(&launched);
                        return Err(err);
                    }
                };
                match ctx.deployer.launch(image, node).await {
                    Ok(inst) => {
                        launched.push(Rc::clone(&inst));
                        replicas.push(inst);
                    }
                    Err(err) => {
                        self.rollback(&launched);
                        return Err(err);
                    }
                }
            }
            fresh.push(ReplicaSet::new(replicas, image));
        }
        for inst in &launched {
            if let Err(err) = self.await_healthy(inst).await {
                ctx.metrics.bump("split_health_timeouts");
                self.rollback(&launched);
                return Err(err);
            }
        }
        Ok(fresh)
    }

    /// Tear down every replica of never-routed replacement sets.
    fn rollback_sets(&self, fresh: &[Rc<ReplicaSet>]) {
        for set in fresh {
            self.rollback(&set.live());
        }
    }

    /// Node a split replacement deploys to: the group's home node, except
    /// under node pressure, where the scheduler places each replacement
    /// wherever the cluster has headroom (that split exists to shed the
    /// home node).
    fn replacement_node(
        &self,
        image: ImageId,
        reason: SplitReason,
        home: Option<NodeId>,
    ) -> Result<NodeId> {
        if reason != SplitReason::NodePressure {
            return Ok(home.unwrap_or(NodeId(0)));
        }
        let code_mb: f64 = self
            .ctx
            .containers
            .image(image)?
            .functions
            .iter()
            .map(|(_, mb)| mb)
            .sum();
        self.ctx.scheduler.place(self.ctx.config.ram.base_instance_mb + code_mb)
    }

    /// Tear down never-routed replacement instances.
    fn rollback(&self, fresh: &[Rc<Instance>]) {
        for inst in fresh {
            let _ = inst.begin_drain();
            let _ = self.ctx.containers.terminate(inst);
        }
    }
}
