//! Platform-flavored instance deployment.
//!
//! tinyFaaS launches containers directly; Kubernetes goes through the
//! declarative machinery — a Deployment object is reconciled into a pod on
//! the controller's next loop iteration.  The reconciler-gated path charges
//! that control-loop delay (paper §2.1: orchestration frameworks trade
//! "additional architectural complexity and runtime overhead" for features).
//!
//! Since the cluster subsystem, every launch targets an explicit node (a
//! single-node platform always targets node 0): the kubelet analogy — the
//! scheduler picks the node, the deployer realizes the pod there.

use std::rc::Rc;

use crate::cluster::{Cluster, NodeId};
use crate::containerd::{ImageId, Instance};
use crate::error::Result;
use crate::exec;

/// Instance deployment strategy.
#[derive(Clone)]
pub enum Deployer {
    /// tinyFaaS: start the container immediately.
    Direct { cluster: Cluster },
    /// Kubernetes: the launch takes effect on the next reconcile tick
    /// (ticks at multiples of `interval_ms` on the virtual clock).
    Reconciled { cluster: Cluster, interval_ms: f64 },
}

impl Deployer {
    pub fn direct(cluster: Cluster) -> Self {
        Deployer::Direct { cluster }
    }

    pub fn reconciled(cluster: Cluster, interval_ms: f64) -> Self {
        assert!(interval_ms > 0.0, "reconcile interval must be positive");
        Deployer::Reconciled { cluster, interval_ms }
    }

    /// Launch an instance of `image` on `node` under this strategy.  The
    /// returned instance is `Booting`; the caller health-gates it.
    pub async fn launch(&self, image: ImageId, node: NodeId) -> Result<Rc<Instance>> {
        match self {
            Deployer::Direct { cluster } => cluster.launch_on(node, image),
            Deployer::Reconciled { cluster, interval_ms } => {
                // wait for the next control-loop tick
                let now = exec::now().as_millis_f64();
                let next_tick = (now / interval_ms).floor() * interval_ms + interval_ms;
                exec::sleep_ms(next_tick - now).await;
                cluster.launch_on(node, image)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::containerd::FsManifest;
    use crate::exec::{now, run_virtual, sleep_ms};

    fn cluster() -> (Cluster, ImageId) {
        let mut cfg = PlatformConfig::kube();
        cfg.cluster.nodes = 2;
        let cluster = Cluster::new(&Rc::new(cfg));
        let img = cluster
            .control()
            .register_image(FsManifest::function_code("a", 1), vec![("a".into(), 9.0)]);
        (cluster, img)
    }

    #[test]
    fn direct_launch_is_immediate_and_lands_on_the_node() {
        run_virtual(async {
            let (cluster, img) = cluster();
            let t0 = now().as_millis_f64();
            let inst =
                Deployer::direct(cluster.clone()).launch(img, NodeId(1)).await.unwrap();
            assert_eq!(now().as_millis_f64(), t0);
            assert_eq!(cluster.node_of(inst.id()), Some(NodeId(1)));
        });
    }

    #[test]
    fn reconciled_launch_waits_for_tick() {
        run_virtual(async {
            let (cluster, img) = cluster();
            let dep = Deployer::reconciled(cluster, 500.0);
            sleep_ms(120.0).await;
            let _inst = dep.launch(img, NodeId(0)).await.unwrap();
            assert_eq!(now().as_millis_f64(), 500.0);
        });
    }

    #[test]
    fn reconciled_on_boundary_goes_to_next_tick() {
        run_virtual(async {
            let (cluster, img) = cluster();
            let dep = Deployer::reconciled(cluster, 250.0);
            sleep_ms(250.0).await; // exactly at a tick
            let _inst = dep.launch(img, NodeId(0)).await.unwrap();
            assert_eq!(now().as_millis_f64(), 500.0);
        });
    }
}
