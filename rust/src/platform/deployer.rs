//! Platform-flavored instance deployment.
//!
//! tinyFaaS launches containers directly; Kubernetes goes through the
//! declarative machinery — a Deployment object is reconciled into a pod on
//! the controller's next loop iteration.  The reconciler-gated path charges
//! that control-loop delay (paper §2.1: orchestration frameworks trade
//! "additional architectural complexity and runtime overhead" for features).

use std::rc::Rc;

use crate::containerd::{ContainerRuntime, ImageId, Instance};
use crate::error::Result;
use crate::exec;

/// Instance deployment strategy.
#[derive(Clone)]
pub enum Deployer {
    /// tinyFaaS: start the container immediately.
    Direct { containers: ContainerRuntime },
    /// Kubernetes: the launch takes effect on the next reconcile tick
    /// (ticks at multiples of `interval_ms` on the virtual clock).
    Reconciled { containers: ContainerRuntime, interval_ms: f64 },
}

impl Deployer {
    pub fn direct(containers: ContainerRuntime) -> Self {
        Deployer::Direct { containers }
    }

    pub fn reconciled(containers: ContainerRuntime, interval_ms: f64) -> Self {
        assert!(interval_ms > 0.0, "reconcile interval must be positive");
        Deployer::Reconciled { containers, interval_ms }
    }

    /// Launch an instance of `image` under this strategy.  The returned
    /// instance is `Booting`; the caller health-gates it.
    pub async fn launch(&self, image: ImageId) -> Result<Rc<Instance>> {
        match self {
            Deployer::Direct { containers } => containers.launch(image),
            Deployer::Reconciled { containers, interval_ms } => {
                // wait for the next control-loop tick
                let now = exec::now().as_millis_f64();
                let next_tick = (now / interval_ms).floor() * interval_ms + interval_ms;
                exec::sleep_ms(next_tick - now).await;
                containers.launch(image)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::containerd::FsManifest;
    use crate::exec::{now, run_virtual, sleep_ms};

    fn rt() -> (ContainerRuntime, ImageId) {
        let rt = ContainerRuntime::new(Rc::new(PlatformConfig::kube()));
        let img = rt.register_image(FsManifest::function_code("a", 1), vec![("a".into(), 9.0)]);
        (rt, img)
    }

    #[test]
    fn direct_launch_is_immediate() {
        run_virtual(async {
            let (rt, img) = rt();
            let t0 = now().as_millis_f64();
            let _inst = Deployer::direct(rt).launch(img).await.unwrap();
            assert_eq!(now().as_millis_f64(), t0);
        });
    }

    #[test]
    fn reconciled_launch_waits_for_tick() {
        run_virtual(async {
            let (rt, img) = rt();
            let dep = Deployer::reconciled(rt, 500.0);
            sleep_ms(120.0).await;
            let _inst = dep.launch(img).await.unwrap();
            assert_eq!(now().as_millis_f64(), 500.0);
            // exactly on a tick boundary -> next tick
            let (rt2, img2) = super::tests::rt();
            let dep2 = Deployer::reconciled(rt2, 500.0);
            let _ = dep2; // silence unused in this scope
            let _ = img2;
        });
    }

    #[test]
    fn reconciled_on_boundary_goes_to_next_tick() {
        run_virtual(async {
            let (rt, img) = rt();
            let dep = Deployer::reconciled(rt, 250.0);
            sleep_ms(250.0).await; // exactly at a tick
            let _inst = dep.launch(img).await.unwrap();
            assert_eq!(now().as_millis_f64(), 500.0);
        });
    }
}
