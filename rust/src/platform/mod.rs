//! Platform assembly: wires gateway, container runtime, fabric, handlers,
//! fusion observer, Merger, and the RAM sampler into a deployable FaaS
//! platform.  Two flavors (paper §4): [`PlatformKind::Tiny`] (direct
//! deployment, lean fabric) and [`PlatformKind::Kube`] (Service
//! indirection, reconciler-gated deployment, heavier fabric).

pub mod deployer;

use std::cell::Cell;
use std::rc::Rc;

use crate::apps::AppSpec;
use crate::billing::BillingLedger;
use crate::config::{ComputeMode, PlatformConfig, PlatformKind};
use crate::containerd::{ContainerRuntime, FsManifest, InstanceState};
use crate::error::Result;
use crate::exec;
use crate::exec::channel::mpsc;
use crate::exec::SimInstant;
use crate::fusion::Observer;
use crate::gateway::Gateway;
use crate::handler::Dispatcher;
use crate::merger::{Merger, MergerCtx};
use crate::metrics::Recorder;
use crate::netsim::Fabric;
use crate::runtime::{ArtifactSet, ComputeService};

use deployer::Deployer;

/// A running FaaS platform hosting one application.
pub struct Platform {
    pub config: Rc<PlatformConfig>,
    pub app: AppSpec,
    pub containers: ContainerRuntime,
    pub gateway: Gateway,
    pub metrics: Recorder,
    pub observer: Rc<Observer>,
    pub billing: BillingLedger,
    dispatcher: Dispatcher,
    start: SimInstant,
    sampler_stop: Rc<Cell<bool>>,
}

impl Platform {
    /// Deploy `app` on a platform assembled from `config`: one instance per
    /// function, all routes installed, Merger + RAM sampler running.
    /// Resolves when every initial instance is healthy.
    pub async fn deploy(app: AppSpec, config: PlatformConfig) -> Result<Rc<Platform>> {
        let config = Rc::new(config);
        let containers = ContainerRuntime::new(Rc::clone(&config));
        let gateway = Gateway::new();
        let metrics = Recorder::new();
        let fabric = Fabric::new(config.latency.clone(), config.seed);

        let compute = match config.compute {
            ComputeMode::Disabled => ComputeService::disabled(),
            mode => ComputeService::new(ArtifactSet::cached(&config.artifacts_dir)?, mode),
        };

        // fusion plumbing
        let (fusion_tx, fusion_rx) = mpsc();
        let observer = Rc::new(Observer::new(config.fusion.clone(), &app, fusion_tx));

        // initial deployment: one image + instance per function
        let mut instances = Vec::new();
        for f in app.functions() {
            let image = containers.register_image(
                FsManifest::function_code(&f.name, f.code_kb),
                vec![(f.name.clone(), f.code_mb)],
            );
            let inst = containers.launch(image)?;
            gateway.set_route(&f.name, Rc::clone(&inst));
            instances.push(inst);
        }
        // wait for the fleet to boot
        loop {
            if instances.iter().all(|i| i.state() == InstanceState::Healthy) {
                break;
            }
            exec::sleep_ms(config.latency.health_interval_ms).await;
        }
        // all recorded series share this epoch (deploy-complete instant)
        metrics.set_epoch_now();

        let billing = BillingLedger::new();
        let dispatcher = Dispatcher::new(
            app.clone(),
            Rc::clone(&config),
            fabric,
            gateway.clone(),
            compute,
            Rc::clone(&observer),
            metrics.clone(),
            billing.clone(),
        );

        // platform-flavored deployer for fused instances
        let dep = match config.kind {
            PlatformKind::Tiny => Deployer::direct(containers.clone()),
            PlatformKind::Kube => {
                Deployer::reconciled(containers.clone(), config.latency.reconcile_interval_ms)
            }
        };

        // Merger service
        let merger = Merger::new(MergerCtx {
            config: Rc::clone(&config),
            containers: containers.clone(),
            gateway: gateway.clone(),
            observer: Rc::clone(&observer),
            metrics: metrics.clone(),
            deployer: dep,
        });
        exec::spawn(merger.run(fusion_rx));

        // RAM sampler
        let sampler_stop = Rc::new(Cell::new(false));
        {
            let stop = Rc::clone(&sampler_stop);
            let containers = containers.clone();
            let metrics = metrics.clone();
            let interval = config.ram.sample_interval_ms;
            exec::spawn(async move {
                while !stop.get() {
                    let t = metrics.rel_now_ms();
                    metrics.record_ram(t, containers.total_ram_mb(), containers.live_count());
                    exec::sleep_ms(interval).await;
                }
            });
        }

        Ok(Rc::new(Platform {
            config,
            app,
            containers,
            gateway,
            metrics,
            observer,
            billing,
            dispatcher,
            start: exec::now(),
            sampler_stop,
        }))
    }

    /// Invoke the application's entry function with `payload`.
    pub async fn invoke(&self, payload: Vec<f32>) -> Result<Vec<f32>> {
        self.dispatcher.invoke(&self.app.entry.clone(), payload).await
    }

    /// Invoke an arbitrary function (targeted tests / custom clients).
    pub async fn invoke_function(&self, function: &str, payload: Vec<f32>) -> Result<Vec<f32>> {
        self.dispatcher.invoke(function, payload).await
    }

    /// Expected request payload length (f32 count).
    pub fn payload_len(&self) -> usize {
        self.dispatcher.payload_len()
    }

    /// Virtual time the platform finished deploying.
    pub fn start(&self) -> SimInstant {
        self.start
    }

    /// Milliseconds of virtual time since deployment finished.
    pub fn elapsed_ms(&self) -> f64 {
        exec::now().duration_since(self.start).as_secs_f64() * 1e3
    }

    /// Stop background tasks (sampler). The Merger loop ends when the
    /// platform (and its fusion sender) is dropped.
    pub fn shutdown(&self) {
        self.sampler_stop.set(true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::exec::run_virtual;

    fn cfg() -> PlatformConfig {
        PlatformConfig::tiny().with_compute(ComputeMode::Disabled)
    }

    #[test]
    fn deploy_boots_one_instance_per_function() {
        run_virtual(async {
            let p = Platform::deploy(apps::tree(), cfg()).await.unwrap();
            assert_eq!(p.containers.live_count(), 7);
            assert_eq!(p.gateway.len(), 7);
            assert_eq!(p.gateway.distinct_instances(), 7);
            p.shutdown();
        });
    }

    #[test]
    fn invoke_returns_response() {
        run_virtual(async {
            let p = Platform::deploy(apps::chain(3), cfg().vanilla()).await.unwrap();
            let payload = vec![0.5f32; p.payload_len()];
            let out = p.invoke(payload).await.unwrap();
            assert_eq!(out.len(), 64);
            assert!(out.iter().all(|v| v.is_finite()));
            p.shutdown();
        });
    }

    #[test]
    fn vanilla_never_merges() {
        run_virtual(async {
            let p = Platform::deploy(apps::chain(3), cfg().vanilla()).await.unwrap();
            for _ in 0..20 {
                let payload = vec![0.1f32; p.payload_len()];
                p.invoke(payload).await.unwrap();
            }
            exec::sleep_ms(30_000.0).await;
            assert_eq!(p.metrics.merges().len(), 0);
            assert_eq!(p.containers.live_count(), 3);
            p.shutdown();
        });
    }

    #[test]
    fn fusion_converges_chain_to_one_instance() {
        run_virtual(async {
            let p = Platform::deploy(apps::chain(3), cfg()).await.unwrap();
            for _ in 0..30 {
                let payload = vec![0.1f32; p.payload_len()];
                p.invoke(payload).await.unwrap();
                exec::sleep_ms(1_000.0).await;
            }
            exec::sleep_ms(60_000.0).await;
            assert!(p.metrics.merges().len() >= 2, "merges: {:?}", p.metrics.merges());
            assert_eq!(p.gateway.distinct_instances(), 1);
            // originals reclaimed
            assert_eq!(p.containers.live_count(), 1);
            p.shutdown();
        });
    }
}
