//! Platform assembly: wires gateway, container runtime, fabric, handlers,
//! fusion observer, Merger, and the RAM sampler into a deployable FaaS
//! platform.  Two flavors (paper §4): [`PlatformKind::Tiny`] (direct
//! deployment, lean fabric) and [`PlatformKind::Kube`] (Service
//! indirection, reconciler-gated deployment, heavier fabric).

pub mod deployer;

use std::cell::Cell;
use std::collections::{BTreeMap, HashSet};
use std::rc::Rc;

use crate::apps::AppSpec;
use crate::billing::BillingLedger;
use crate::config::{ComputeMode, PlatformConfig, PlatformKind};
use crate::containerd::{ContainerRuntime, FsManifest, ImageId, Instance, InstanceState};
use crate::error::Result;
use crate::exec;
use crate::exec::channel::mpsc;
use crate::exec::SimInstant;
use crate::fusion::{GroupSample, Observer};
use crate::gateway::Gateway;
use crate::handler::Dispatcher;
use crate::merger::{Merger, MergerCtx};
use crate::metrics::Recorder;
use crate::netsim::Fabric;
use crate::runtime::{ArtifactSet, ComputeService};

use deployer::Deployer;

/// Distinct live fused instances (two or more hosted functions) in a
/// routing table — the defusion controller's sampling domain.
pub fn fused_groups_of(gateway: &Gateway) -> Vec<Rc<Instance>> {
    let mut seen = HashSet::new();
    let mut out = Vec::new();
    for (_, inst) in gateway.snapshot() {
        if inst.functions().len() >= 2 && seen.insert(inst.id()) {
            out.push(inst);
        }
    }
    out
}

/// A running FaaS platform hosting one application.
pub struct Platform {
    pub config: Rc<PlatformConfig>,
    pub app: AppSpec,
    pub containers: ContainerRuntime,
    pub gateway: Gateway,
    pub metrics: Recorder,
    pub observer: Rc<Observer>,
    pub billing: BillingLedger,
    dispatcher: Dispatcher,
    start: SimInstant,
    sampler_stop: Rc<Cell<bool>>,
    /// retained single-function images (the split pipeline's redeploy source)
    originals: Rc<BTreeMap<String, ImageId>>,
}

impl Platform {
    /// Deploy `app` on a platform assembled from `config`: one instance per
    /// function, all routes installed, Merger + RAM sampler running.
    /// Resolves when every initial instance is healthy.
    pub async fn deploy(app: AppSpec, config: PlatformConfig) -> Result<Rc<Platform>> {
        let config = Rc::new(config);
        let containers = ContainerRuntime::new(Rc::clone(&config));
        let gateway = Gateway::new();
        let metrics = Recorder::new();
        let fabric = Fabric::new(config.latency.clone(), config.seed);

        let compute = match config.compute {
            ComputeMode::Disabled => ComputeService::disabled(),
            mode => ComputeService::new(ArtifactSet::cached(&config.artifacts_dir)?, mode),
        };

        // fusion plumbing
        let (fusion_tx, fusion_rx) = mpsc();
        let observer = Rc::new(Observer::new(config.fusion.clone(), &app, fusion_tx));

        // initial deployment: one image + instance per function; the images
        // are retained for the lifetime of the platform so the defusion
        // pipeline can always redeploy originals
        let mut instances = Vec::new();
        let mut originals = BTreeMap::new();
        for f in app.functions() {
            let image = containers.register_image(
                FsManifest::function_code(&f.name, f.code_kb),
                vec![(f.name.clone(), f.code_mb)],
            );
            originals.insert(f.name.clone(), image);
            let inst = containers.launch(image)?;
            gateway.set_route(&f.name, Rc::clone(&inst));
            instances.push(inst);
        }
        let originals = Rc::new(originals);
        // wait for the fleet to boot
        loop {
            if instances.iter().all(|i| i.state() == InstanceState::Healthy) {
                break;
            }
            exec::sleep_ms(config.latency.health_interval_ms).await;
        }
        // all recorded series share this epoch (deploy-complete instant)
        metrics.set_epoch_now();

        let billing = BillingLedger::new();
        let dispatcher = Dispatcher::new(
            app.clone(),
            Rc::clone(&config),
            fabric,
            gateway.clone(),
            compute,
            Rc::clone(&observer),
            metrics.clone(),
            billing.clone(),
        );

        // platform-flavored deployer for fused instances
        let dep = match config.kind {
            PlatformKind::Tiny => Deployer::direct(containers.clone()),
            PlatformKind::Kube => {
                Deployer::reconciled(containers.clone(), config.latency.reconcile_interval_ms)
            }
        };

        // Merger service
        let merger = Merger::new(MergerCtx {
            config: Rc::clone(&config),
            containers: containers.clone(),
            gateway: gateway.clone(),
            observer: Rc::clone(&observer),
            metrics: metrics.clone(),
            deployer: dep,
            originals: Rc::clone(&originals),
        });
        exec::spawn(merger.run(fusion_rx));

        // RAM sampler
        let sampler_stop = Rc::new(Cell::new(false));
        {
            let stop = Rc::clone(&sampler_stop);
            let containers = containers.clone();
            let metrics = metrics.clone();
            let interval = config.ram.sample_interval_ms;
            exec::spawn(async move {
                while !stop.get() {
                    let t = metrics.rel_now_ms();
                    metrics.record_ram(t, containers.total_ram_mb(), containers.live_count());
                    exec::sleep_ms(interval).await;
                }
            });
        }

        // Defusion controller: every feedback interval, attribute RAM to
        // each live fused group and hand the samples (plus the trailing
        // latency window's p95) to the Observer, which closes the loop by
        // emitting Split requests for regressing groups.
        if config.fusion.enabled
            && config.fusion.defusion
            && config.fusion.feedback_interval_ms > 0.0
        {
            let stop = Rc::clone(&sampler_stop);
            let gateway = gateway.clone();
            let metrics = metrics.clone();
            let observer = Rc::clone(&observer);
            let interval = config.fusion.feedback_interval_ms;
            exec::spawn(async move {
                while !stop.get() {
                    exec::sleep_ms(interval).await;
                    if stop.get() {
                        break;
                    }
                    let t = metrics.rel_now_ms();
                    let mut samples = Vec::new();
                    for inst in fused_groups_of(&gateway) {
                        let mut functions: Vec<String> =
                            inst.functions().iter().map(|(n, _)| n.clone()).collect();
                        functions.sort();
                        let ram_mb = inst.ram_mb();
                        metrics.record_group_ram(t, functions.join("+"), ram_mb);
                        let window_p95_ms = metrics.p95_window(
                            t - interval,
                            t,
                            crate::metrics::MIN_WINDOW_SAMPLES,
                        );
                        samples.push(GroupSample { functions, ram_mb, window_p95_ms });
                    }
                    if !samples.is_empty() {
                        observer.feedback(&samples);
                    }
                }
            });
        }

        Ok(Rc::new(Platform {
            config,
            app,
            containers,
            gateway,
            metrics,
            observer,
            billing,
            dispatcher,
            start: exec::now(),
            sampler_stop,
            originals,
        }))
    }

    /// Invoke the application's entry function with `payload`.
    pub async fn invoke(&self, payload: Vec<f32>) -> Result<Vec<f32>> {
        self.dispatcher.invoke(&self.app.entry.clone(), payload).await
    }

    /// Invoke an arbitrary function (targeted tests / custom clients).
    pub async fn invoke_function(&self, function: &str, payload: Vec<f32>) -> Result<Vec<f32>> {
        self.dispatcher.invoke(function, payload).await
    }

    /// Expected request payload length (f32 count).
    pub fn payload_len(&self) -> usize {
        self.dispatcher.payload_len()
    }

    /// Retained original image for `function` (the defusion redeploy
    /// source); None for functions the app does not define.
    pub fn original_image(&self, function: &str) -> Option<ImageId> {
        self.originals.get(function).copied()
    }

    /// Live group membership: the functions colocated with `function`
    /// (sorted; a single-element vec means the function is unfused).
    pub fn group_members(&self, function: &str) -> Vec<String> {
        match self.gateway.resolve(function) {
            Ok(inst) => {
                let mut v: Vec<String> =
                    inst.functions().iter().map(|(n, _)| n.clone()).collect();
                v.sort();
                v
            }
            Err(_) => Vec::new(),
        }
    }

    /// Distinct live fused instances (more than one hosted function).
    pub fn fused_groups(&self) -> Vec<Rc<Instance>> {
        fused_groups_of(&self.gateway)
    }

    /// Virtual time the platform finished deploying.
    pub fn start(&self) -> SimInstant {
        self.start
    }

    /// Milliseconds of virtual time since deployment finished.
    pub fn elapsed_ms(&self) -> f64 {
        exec::now().duration_since(self.start).as_secs_f64() * 1e3
    }

    /// Stop background tasks (sampler). The Merger loop ends when the
    /// platform (and its fusion sender) is dropped.
    pub fn shutdown(&self) {
        self.sampler_stop.set(true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::exec::run_virtual;

    fn cfg() -> PlatformConfig {
        PlatformConfig::tiny().with_compute(ComputeMode::Disabled)
    }

    #[test]
    fn deploy_boots_one_instance_per_function() {
        run_virtual(async {
            let p = Platform::deploy(apps::tree(), cfg()).await.unwrap();
            assert_eq!(p.containers.live_count(), 7);
            assert_eq!(p.gateway.len(), 7);
            assert_eq!(p.gateway.distinct_instances(), 7);
            p.shutdown();
        });
    }

    #[test]
    fn invoke_returns_response() {
        run_virtual(async {
            let p = Platform::deploy(apps::chain(3), cfg().vanilla()).await.unwrap();
            let payload = vec![0.5f32; p.payload_len()];
            let out = p.invoke(payload).await.unwrap();
            assert_eq!(out.len(), 64);
            assert!(out.iter().all(|v| v.is_finite()));
            p.shutdown();
        });
    }

    #[test]
    fn vanilla_never_merges() {
        run_virtual(async {
            let p = Platform::deploy(apps::chain(3), cfg().vanilla()).await.unwrap();
            for _ in 0..20 {
                let payload = vec![0.1f32; p.payload_len()];
                p.invoke(payload).await.unwrap();
            }
            exec::sleep_ms(30_000.0).await;
            assert_eq!(p.metrics.merges().len(), 0);
            assert_eq!(p.containers.live_count(), 3);
            p.shutdown();
        });
    }

    #[test]
    fn controller_attributes_group_ram_and_exposes_membership() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.latency.image_build_ms = 300.0;
            cfg.latency.boot_ms = 150.0;
            cfg.fusion.min_observations = 1;
            cfg.fusion.feedback_interval_ms = 1_000.0;
            let p = Platform::deploy(apps::chain(2), cfg).await.unwrap();
            for _ in 0..5 {
                let payload = vec![0.1f32; p.payload_len()];
                p.invoke(payload).await.unwrap();
                exec::sleep_ms(500.0).await;
            }
            exec::sleep_ms(20_000.0).await;
            assert_eq!(p.group_members("s0"), vec!["s0".to_string(), "s1".to_string()]);
            assert_eq!(p.fused_groups().len(), 1);
            assert!(p.original_image("s0").is_some());
            assert!(p.original_image("nope").is_none());
            // the controller attributed RAM to the fused group every tick
            let series = p.metrics.group_ram_for("s0+s1");
            assert!(!series.is_empty(), "no group RAM attribution recorded");
            assert!(series.iter().all(|s| s.ram_mb > 0.0));
            // healthy group under default policy: no splits
            assert!(p.metrics.splits().is_empty());
            p.shutdown();
        });
    }

    #[test]
    fn fusion_converges_chain_to_one_instance() {
        run_virtual(async {
            let p = Platform::deploy(apps::chain(3), cfg()).await.unwrap();
            for _ in 0..30 {
                let payload = vec![0.1f32; p.payload_len()];
                p.invoke(payload).await.unwrap();
                exec::sleep_ms(1_000.0).await;
            }
            exec::sleep_ms(60_000.0).await;
            assert!(p.metrics.merges().len() >= 2, "merges: {:?}", p.metrics.merges());
            assert_eq!(p.gateway.distinct_instances(), 1);
            // originals reclaimed
            assert_eq!(p.containers.live_count(), 1);
            p.shutdown();
        });
    }
}
