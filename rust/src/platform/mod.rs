//! Platform assembly: wires gateway, container runtime, fabric, handlers,
//! fusion observer, Merger, and the RAM sampler into a deployable FaaS
//! platform.  Two flavors (paper §4): [`PlatformKind::Tiny`] (direct
//! deployment, lean fabric) and [`PlatformKind::Kube`] (Service
//! indirection, reconciler-gated deployment, heavier fabric).

pub mod deployer;

use std::cell::Cell;
use std::collections::{BTreeMap, HashSet};
use std::rc::Rc;

use crate::apps::AppSpec;
use crate::billing::BillingLedger;
use crate::cluster::{Cluster, NodeId, Scheduler};
use crate::config::{ComputeMode, MergePolicyKind, PlannerKind, PlatformConfig, PlatformKind};
use crate::containerd::{ContainerRuntime, FsManifest, ImageId, Instance, InstanceState};
use crate::error::Result;
use crate::exec;
use crate::exec::channel::mpsc;
use crate::exec::SimInstant;
use crate::fusion::{
    plan, FnAttribution, FnSignals, GroupSample, NodeLoad, NodeSample, Observer,
};
use crate::gateway::Gateway;
use crate::handler::Dispatcher;
use crate::merger::{Merger, MergerCtx};
use crate::metrics::{NodeRamSample, PlanEvent, Recorder};
use crate::netsim::Fabric;
use crate::runtime::{ArtifactSet, ComputeService};
use crate::util::intern::{GroupKey, Sym};

use deployer::Deployer;

/// Distinct live fused instances (two or more hosted functions) in a
/// routing table — the defusion controller's sampling domain.
pub fn fused_groups_of(gateway: &Gateway) -> Vec<Rc<Instance>> {
    let mut seen = HashSet::new();
    let mut out = Vec::new();
    for (_, inst) in gateway.snapshot() {
        if inst.fn_count() >= 2 && seen.insert(inst.id()) {
            out.push(inst);
        }
    }
    out
}

/// Check the routing invariants any quiescent topology must satisfy, no
/// matter what Fuse/Split/Evict/Scale history produced it:
///
/// 1. every app function has exactly one route, to a replica set whose
///    replicas are all **live** and all actively host it;
/// 2. no function is served by two replica sets — distinct sets' active
///    hosting sets are pairwise disjoint (replicas *within* one set
///    deliberately serve the same functions);
/// 3. the routing table plus the warm pool is a bijection onto the live
///    instances: every live instance is either a routed replica or a
///    pooled blank, and every routed replica is live.
///
/// Returns a description of the first violation (the property suite's
/// and mutation checks' shared oracle).  Call only after drains settle —
/// mid-pipeline topologies legitimately hold originals that are still
/// draining.
pub fn routing_invariants(platform: &Platform) -> std::result::Result<(), String> {
    let sets = platform.gateway.snapshot_sets();
    for f in platform.app.functions() {
        if !sets.iter().any(|(name, _)| name == &f.name) {
            return Err(format!("function `{}` has no route", f.name));
        }
    }
    for (function, set) in &sets {
        for inst in set.replicas() {
            if !inst.state().is_live() {
                return Err(format!(
                    "`{function}` routed to dead replica {}",
                    inst.id()
                ));
            }
            if !inst.hosts(function) {
                return Err(format!(
                    "`{function}` routed to replica {} which does not actively host it",
                    inst.id()
                ));
            }
        }
    }
    let mut owner: BTreeMap<String, usize> = BTreeMap::new();
    let mut seen = HashSet::new();
    for (_, set) in &sets {
        let key = Rc::as_ptr(set) as usize;
        if !seen.insert(key) {
            continue;
        }
        for inst in set.replicas() {
            for (f, _) in inst.functions() {
                if let Some(prev) = owner.insert(f.clone(), key) {
                    if prev != key {
                        return Err(format!(
                            "`{f}` actively hosted by two live replica sets \
                             (replica {} is in the second)",
                            inst.id()
                        ));
                    }
                }
            }
        }
    }
    let live = platform.cluster.live_count();
    let routed = platform.gateway.distinct_instances();
    let pooled = platform
        .scaler
        .pool()
        .iter()
        .filter(|i| i.state() != InstanceState::Terminated)
        .count();
    if routed + pooled != live {
        return Err(format!(
            "routing table covers {routed} distinct replicas (+{pooled} \
             warm-pooled) but {live} are live"
        ));
    }
    Ok(())
}

/// A running FaaS platform hosting one application.
pub struct Platform {
    pub config: Rc<PlatformConfig>,
    pub app: AppSpec,
    /// node-0's runtime — *the* runtime on a single-node platform; on a
    /// multi-node cluster use [`Platform::cluster`] for fleet-wide views
    pub containers: ContainerRuntime,
    pub cluster: Cluster,
    pub gateway: Gateway,
    pub metrics: Recorder,
    pub observer: Rc<Observer>,
    pub billing: BillingLedger,
    /// replica supplier: warm pool + cold boots (autoscaler and
    /// scale-from-zero both draw from it)
    pub scaler: Rc<crate::replica::Scaler>,
    /// request-level span tracer (ISSUE 9; disabled under the default
    /// `trace.sample_every = 0` — a zero-cost no-op)
    pub tracer: crate::trace::Tracer,
    dispatcher: Dispatcher,
    start: SimInstant,
    sampler_stop: Rc<Cell<bool>>,
    /// retained single-function images (the split pipeline's redeploy source)
    originals: Rc<BTreeMap<String, ImageId>>,
}

impl Platform {
    /// Deploy `app` on a platform assembled from `config`: one instance per
    /// function, all routes installed, Merger + RAM sampler running.
    /// Resolves when every initial instance is healthy.
    pub async fn deploy(app: AppSpec, config: PlatformConfig) -> Result<Rc<Platform>> {
        // The merge planner's only signal source is the controller tick: a
        // disabled tick would silently refuse every candidate forever
        // (admit_merge never sees window signals), so reject the config
        // instead of shipping a platform that quietly never fuses.
        if config.fusion.enabled
            && config.fusion.merge_policy == MergePolicyKind::CostModel
            && config.fusion.feedback_interval_ms <= 0.0
        {
            return Err(crate::error::Error::Config(
                "merge-policy `cost` needs a positive --feedback-interval-ms: \
                 the admission planner scores pairs from controller-tick window \
                 signals"
                    .into(),
            ));
        }
        // The global re-planner's only input is the controller tick's
        // snapshot (signals + node loads); same reasoning as above.
        if config.fusion.enabled
            && config.fusion.planner == PlannerKind::Global
            && (config.fusion.feedback_interval_ms <= 0.0
                || config.fusion.replan_interval_ticks == 0)
        {
            return Err(crate::error::Error::Config(
                "--planner global needs a positive --feedback-interval-ms and \
                 --replan-ticks: the planner searches over controller-tick \
                 snapshots"
                    .into(),
            ));
        }
        // Replica-set bounds: a zero ceiling would deploy routes no replica
        // can ever serve, and an empty/inverted floor is a config typo, not
        // a topology.  Reject both up front with the flag names.
        if config.scaling.replicas_max == 0 {
            return Err(crate::error::Error::Config(
                "--replicas-max 0 would deploy routes no replica can serve; \
                 use --replicas-max 1 for the seed's one-instance-per-function \
                 shape"
                    .into(),
            ));
        }
        if config.scaling.replicas_min == 0
            || config.scaling.replicas_min > config.scaling.replicas_max
        {
            return Err(crate::error::Error::Config(format!(
                "--replicas-min {} must be between 1 and --replicas-max {}",
                config.scaling.replicas_min, config.scaling.replicas_max
            )));
        }
        // A warm pool that cannot physically fit the cluster would fail
        // half-deployed at prewarm time; refuse it whole instead.
        if config.cluster.node_capacity_mb > 0.0 {
            let fleet_mb =
                config.cluster.node_capacity_mb * config.cluster.nodes.max(1) as f64;
            let pool_mb = config.scaling.warm_pool as f64 * config.ram.base_instance_mb;
            if pool_mb > fleet_mb {
                return Err(crate::error::Error::Config(format!(
                    "--warm-pool {} needs {pool_mb:.0} MiB of blank instances \
                     but the cluster caps at {fleet_mb:.0} MiB",
                    config.scaling.warm_pool
                )));
            }
        }
        let config = Rc::new(config);
        let cluster = Cluster::new(&config);
        let scheduler = Scheduler::new(config.cluster.placement, cluster.clone());
        let containers = cluster.control();
        let gateway = Gateway::new();
        // Windowed retention must cover every trailing window a consumer
        // queries: the controller's feedback interval and the merger's
        // baseline lookback (10x interval, min 10s) — doubled for slack.
        let mut rec = config.recording.clone();
        rec.ensure_retention_ms(config.fusion.baseline_lookback_ms() * 2.0);
        // under windowed recording the billing ledger is bounded to the
        // same horizon (it is O(requests) otherwise)
        let billing_retention_ms = if rec.level == crate::metrics::RecordingLevel::Windowed {
            rec.retention_ms()
        } else {
            0.0
        };
        let metrics = Recorder::with_config(rec);
        let fabric = Fabric::new(config.latency.clone(), config.seed);

        let compute = match config.compute {
            ComputeMode::Disabled => ComputeService::disabled(),
            mode => ComputeService::new(ArtifactSet::cached(&config.artifacts_dir)?, mode),
        };

        // fusion plumbing (the shared recorder receives the merge planner's
        // admission scores + auto-tune regrets)
        let (fusion_tx, fusion_rx) = mpsc();
        let observer = Rc::new(Observer::with_metrics(
            config.fusion.clone(),
            &app,
            fusion_tx,
            metrics.clone(),
        ));

        // initial deployment: one image + instance per function, each
        // placed by the scheduler's policy (bin-pack / spread /
        // fusion-affinity; a single-node cluster maps everything to
        // node 0).  The images are retained for the lifetime of the
        // platform so the defusion pipeline can always redeploy originals.
        let placement = scheduler.place_app(&app, &config.ram)?;
        let mut instances = Vec::new();
        let mut originals = BTreeMap::new();
        for f in app.functions() {
            let image = containers.register_image(
                FsManifest::function_code(&f.name, f.code_kb),
                vec![(f.name.clone(), f.code_mb)],
            );
            originals.insert(f.name.clone(), image);
            let node = placement.get(&f.name).copied().unwrap_or(NodeId(0));
            let inst = cluster.launch_on(node, image)?;
            instances.push(Rc::clone(&inst));
            let set = crate::replica::ReplicaSet::singleton(inst);
            // --replicas-min above 1: boot the floor's extra replicas
            // alongside the founder, each placed against the live ledger
            for _ in 1..config.scaling.replicas_min {
                let extra_node = scheduler.place(config.ram.base_instance_mb + f.code_mb)?;
                let extra = cluster.launch_on(extra_node, image)?;
                set.add(Rc::clone(&extra));
                instances.push(extra);
            }
            gateway.set_route_set(&f.name, set);
        }
        let originals = Rc::new(originals);
        // warm pool: pre-boot blank instances alongside the initial fleet
        // (their boots overlap the health wait below)
        let scaler = crate::replica::Scaler::new(
            Rc::clone(&config),
            cluster.clone(),
            scheduler.clone(),
            metrics.clone(),
        );
        scaler.prewarm()?;
        // wait for the fleet to boot
        loop {
            if instances.iter().all(|i| i.state() == InstanceState::Healthy) {
                break;
            }
            exec::sleep_ms(config.latency.health_interval_ms).await;
        }
        // all recorded series share this epoch (deploy-complete instant)
        metrics.set_epoch_now();

        let billing = if billing_retention_ms > 0.0 {
            BillingLedger::windowed(billing_retention_ms)
        } else {
            BillingLedger::new()
        };
        let tracer = crate::trace::Tracer::new(&config.trace, config.seed);
        let dispatcher = Dispatcher::new(
            app.clone(),
            Rc::clone(&config),
            fabric,
            gateway.clone(),
            cluster.clone(),
            compute,
            Rc::clone(&observer),
            metrics.clone(),
            billing.clone(),
            tracer.clone(),
        );
        // the handler's scale-from-zero path revives idle routes through
        // the same warm-pool/cold-boot engine the autoscaler uses
        dispatcher.set_scaler(Rc::clone(&scaler));

        // platform-flavored deployer for fused instances
        let dep = match config.kind {
            PlatformKind::Tiny => Deployer::direct(cluster.clone()),
            PlatformKind::Kube => {
                Deployer::reconciled(cluster.clone(), config.latency.reconcile_interval_ms)
            }
        };

        // Merger service
        let merger = Merger::new(MergerCtx {
            config: Rc::clone(&config),
            containers: containers.clone(),
            cluster: cluster.clone(),
            scheduler: scheduler.clone(),
            gateway: gateway.clone(),
            observer: Rc::clone(&observer),
            metrics: metrics.clone(),
            deployer: dep,
            originals: Rc::clone(&originals),
        });
        exec::spawn(merger.run(fusion_rx));

        // RAM sampler: the platform-wide series plus one series per node
        // (on a single-node platform the node-0 series mirrors the total)
        let sampler_stop = Rc::new(Cell::new(false));
        {
            let stop = Rc::clone(&sampler_stop);
            let cluster = cluster.clone();
            let metrics = metrics.clone();
            let interval = config.ram.sample_interval_ms;
            exec::spawn(async move {
                while !stop.get() {
                    let t = metrics.rel_now_ms();
                    metrics.record_ram(t, cluster.total_ram_mb(), cluster.live_count());
                    for node in cluster.nodes() {
                        metrics.record_node_ram(NodeRamSample {
                            t_ms: t,
                            node: node.id(),
                            ram_mb: node.ram_mb(),
                            capacity_mb: node.capacity_mb(),
                            instances: node.live_count(),
                        });
                    }
                    exec::sleep_ms(interval).await;
                }
            });
        }

        // Controller loop: every feedback interval, attribute RAM (group and
        // per-function), per-function handler p95s, and the billing ledger's
        // trailing window.  Fused groups feed the *defusion* side
        // (Observer::feedback -> Split/Evict); every routed function —
        // fused or not — additionally feeds the *merge planner*
        // (Observer::update_fn_signals -> cost-aware Fuse admission), so
        // the loop also runs when defusion is off but the cost-model merge
        // policy needs its window signals.  On capped multi-node clusters
        // the same tick drives the *node pressure* controller
        // (Observer::node_feedback -> Migrate, or Split as the fallback).
        let pressure_managed =
            cluster.node_count() > 1 && config.cluster.node_capacity_mb > 0.0;
        if config.fusion.enabled
            && config.fusion.feedback_interval_ms > 0.0
            && (config.fusion.defusion
                || config.fusion.merge_policy == MergePolicyKind::CostModel
                || config.fusion.planner == PlannerKind::Global
                || pressure_managed)
        {
            let stop = Rc::clone(&sampler_stop);
            let gateway = gateway.clone();
            let metrics = metrics.clone();
            let observer = Rc::clone(&observer);
            let billing = billing.clone();
            let cluster = cluster.clone();
            let entry = app.entry.clone();
            let interval = config.fusion.feedback_interval_ms;
            let cfg = Rc::clone(&config);
            let planner_global = config.fusion.planner == PlannerKind::Global;
            let replan_ticks = config.fusion.replan_interval_ticks.max(1);
            // predicted one-off co-location cost the merge planner amortizes
            let migration_est_ms = config.latency.boot_ms
                + config.latency.health_interval_ms
                    * config.latency.health_checks_required as f64;
            exec::spawn(async move {
                // reused across ticks: interned member buffer for the
                // canonical GroupKey lookup (zero steady-state allocation)
                let mut member_syms: Vec<Sym> = Vec::new();
                // global re-planner state: tick countdown, monotonic plan
                // ids, and the last emitted plan awaiting its realized
                // objective at the next snapshot
                let mut replan_tick: u32 = 0;
                let mut next_plan_id: u64 = 1;
                let mut awaiting_realize: Option<(u64, f64, f64)> = None;
                while !stop.get() {
                    exec::sleep_ms(interval).await;
                    if stop.get() {
                        break;
                    }
                    let t = metrics.rel_now_ms();
                    let from = t - interval;
                    let window_s = interval / 1e3;
                    let mut samples = Vec::new();
                    // per-function RAM shares inside fused groups, reused by
                    // the merge-planner signals below
                    let mut fused_ram_share: BTreeMap<Sym, f64> = BTreeMap::new();
                    for inst in fused_groups_of(&gateway) {
                        let hosted = inst.functions();
                        let mut functions: Vec<String> =
                            hosted.iter().map(|(n, _)| n.clone()).collect();
                        functions.sort();
                        member_syms.clear();
                        member_syms.extend(functions.iter().map(|n| Sym::intern(n)));
                        let group_key = GroupKey::from_members(&member_syms);
                        let ram_mb = inst.ram_mb();
                        metrics.record_group_ram(t, group_key, ram_mb);
                        // The e2e latency window is an *entry-route* signal:
                        // attributing it to every group would let one group's
                        // regression raise every other group's score (the
                        // blunt-signal gap this controller exists to close).
                        // Interior groups get NaN — their latency signal is
                        // the per-function handler series below.
                        let window_p95_ms = if functions.iter().any(|f| *f == entry) {
                            metrics.p95_window(from, t, crate::metrics::MIN_WINDOW_SAMPLES)
                        } else {
                            f64::NAN
                        };
                        // per-function attribution weighted by in-flight
                        // ownership (equal share when idle; see
                        // metrics::attribute_ram): members sum to the
                        // instance's RAM
                        let in_flight: Vec<u64> =
                            hosted.iter().map(|(n, _)| inst.fn_inflight(n)).collect();
                        let shares =
                            crate::metrics::attribute_ram(ram_mb, &hosted, &in_flight);
                        let mut per_fn = Vec::with_capacity(shares.len());
                        for (name, fn_ram) in &shares {
                            let name_sym = Sym::intern(name);
                            metrics.record_fn_ram(t, group_key, name_sym, *fn_ram);
                            fused_ram_share.insert(name_sym, *fn_ram);
                            per_fn.push(FnAttribution {
                                function: name.clone(),
                                ram_mb: *fn_ram,
                                p95_ms: metrics.fn_p95_window_sym(
                                    name_sym,
                                    from,
                                    t,
                                    crate::metrics::MIN_WINDOW_SAMPLES,
                                ),
                                gb_seconds: billing.gb_seconds_window_sym(name_sym, from, t),
                            });
                        }
                        samples.push(GroupSample {
                            functions,
                            ram_mb,
                            window_p95_ms,
                            window_s,
                            per_fn,
                        });
                    }
                    // merge planner input: window signals for EVERY routed
                    // function (a singleton's attributed RAM is its whole
                    // instance — what fusing it would actually add)
                    let mut signals = Vec::new();
                    for (function, inst) in gateway.snapshot_syms() {
                        let ram_mb = fused_ram_share
                            .get(&function)
                            .copied()
                            .unwrap_or_else(|| inst.ram_mb());
                        signals.push(FnSignals {
                            function,
                            ram_mb,
                            p95_ms: metrics.fn_p95_window_sym(
                                function,
                                from,
                                t,
                                crate::metrics::MIN_WINDOW_SAMPLES,
                            ),
                            gb_seconds: billing.gb_seconds_window_sym(function, from, t),
                            billed_ms: billing.billed_ms_window_sym(function, from, t),
                            self_ms: metrics.fn_self_ms_window_sym(function, from, t),
                            window_s,
                            node: cluster.node_of(inst.id()),
                            // per-replica RAM signals scale with the count
                            // when the planner prices a fusion
                            replicas: gateway
                                .resolve_set_sym(function)
                                .map(|s| s.live_len())
                                .unwrap_or(1)
                                .max(1) as u32,
                        });
                    }
                    // cluster view: per-node loads price cross-node
                    // co-location in the merge planner, and capped nodes
                    // feed the pressure controller
                    if cluster.node_count() > 1 {
                        let loads: Vec<NodeLoad> = cluster
                            .nodes()
                            .iter()
                            .map(|n| NodeLoad {
                                node: n.id(),
                                ram_mb: n.ram_mb(),
                                capacity_mb: n.capacity_mb(),
                            })
                            .collect();
                        observer.update_cluster_view(loads, migration_est_ms);
                        if pressure_managed {
                            let node_samples: Vec<NodeSample> = cluster
                                .nodes()
                                .iter()
                                .map(|n| NodeSample {
                                    node: n.id(),
                                    ram_mb: n.ram_mb(),
                                    capacity_mb: n.capacity_mb(),
                                    instances: n
                                        .containers()
                                        .live_instances()
                                        .iter()
                                        .filter(|i| i.state() == InstanceState::Healthy)
                                        .map(|i| {
                                            let mut fns: Vec<String> = i
                                                .functions()
                                                .iter()
                                                .map(|(f, _)| f.clone())
                                                .collect();
                                            fns.sort();
                                            (fns, i.ram_mb())
                                        })
                                        .collect(),
                                })
                                .collect();
                            observer.node_feedback(&node_samples);
                        }
                    }
                    observer.update_fn_signals(signals);
                    if !samples.is_empty() {
                        observer.feedback(&samples);
                    }
                    // Global re-planner (ISSUE 8): every N ticks, freeze a
                    // snapshot, price the previous plan's realized steady
                    // state, and search for a better whole-graph partition.
                    if planner_global {
                        replan_tick += 1;
                        if replan_tick >= replan_ticks {
                            replan_tick = 0;
                            let snap = observer.plan_snapshot();
                            if let Some((id, before, after)) = awaiting_realize.take() {
                                metrics.record_plan(PlanEvent {
                                    t_ms: metrics.rel_now_ms(),
                                    plan_id: id,
                                    kind: "realized".to_string(),
                                    actions: 0,
                                    predicted_before: before,
                                    predicted_after: after,
                                    realized: plan::snapshot_objective(&snap, &cfg.fusion),
                                    detail: String::new(),
                                });
                            }
                            let plan_seed = cfg
                                .seed
                                .wrapping_add(next_plan_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                            if let Some(p) =
                                plan::search(&snap, &cfg.fusion, plan_seed, next_plan_id)
                            {
                                next_plan_id += 1;
                                metrics.record_plan(PlanEvent {
                                    t_ms: metrics.rel_now_ms(),
                                    plan_id: p.id,
                                    kind: "planned".to_string(),
                                    actions: p.actions.len() as u32,
                                    predicted_before: p.predicted_before,
                                    predicted_after: p.predicted_after,
                                    realized: f64::NAN,
                                    detail: p.summary(),
                                });
                                awaiting_realize =
                                    Some((p.id, p.predicted_before, p.predicted_after));
                                observer.submit_plan(p);
                            }
                        }
                    }
                }
            });
        }

        // Autoscaler: every scale interval, size each route's replica set
        // from its summed in-flight count (see `replica::desired_replicas`),
        // scaling up through the warm pool and down by draining the idlest
        // replicas; scale-to-zero after the idle horizon.  Never spawned at
        // the seed defaults (`--replicas-max 1`, no idle horizon).
        if config.scaling.autoscaler_armed() {
            let stop = Rc::clone(&sampler_stop);
            let gateway = gateway.clone();
            let metrics = metrics.clone();
            let cluster = cluster.clone();
            let scaler = Rc::clone(&scaler);
            let sc = config.scaling.clone();
            exec::spawn(async move {
                while !stop.get() {
                    exec::sleep_ms(sc.scale_interval_ms).await;
                    if stop.get() {
                        break;
                    }
                    let mut seen: HashSet<usize> = HashSet::new();
                    for (label, set) in gateway.snapshot_sets() {
                        if !seen.insert(Rc::as_ptr(&set) as usize) {
                            continue; // fused set: one decision per set
                        }
                        if set.scale_pending() {
                            continue; // a scale-from-zero revival is in flight
                        }
                        if set.is_retired() {
                            // a fuse/split cutover replaced this set while
                            // the tick was mid-iteration (add_replica
                            // awaits); its replicas are already draining
                            continue;
                        }
                        let live = set.live_len() as u32;
                        let desired = crate::replica::desired_replicas(
                            set.total_inflight(),
                            sc.target_inflight,
                            sc.replicas_min,
                            sc.replicas_max,
                            set.idle_ms(metrics.rel_now_ms()),
                            sc.idle_horizon_ms,
                        );
                        if desired > live {
                            for _ in live..desired {
                                if scaler.add_replica(&label, &set, "burst").await.is_err() {
                                    break; // cluster full: retry next tick
                                }
                                metrics.bump("scale_ups");
                            }
                        } else if desired < live {
                            let reason =
                                if desired == 0 { "scale-to-zero" } else { "scale-down" };
                            for victim in set.drain_candidates((live - desired) as usize) {
                                set.remove(victim.id());
                                if victim.begin_drain().is_ok() {
                                    let rt = cluster
                                        .node_of(victim.id())
                                        .and_then(|n| cluster.node(n).ok())
                                        .map(|n| n.containers().clone())
                                        .unwrap_or_else(|| cluster.control());
                                    crate::containerd::reclaim_when_drained(
                                        rt,
                                        metrics.clone(),
                                        victim,
                                    );
                                }
                            }
                            gateway.bump_version();
                            metrics.record_scale(crate::metrics::ScaleEvent {
                                t_ms: metrics.rel_now_ms(),
                                function: label.clone(),
                                from: live,
                                to: desired,
                                reason,
                                warm: false,
                            });
                            metrics.bump(if desired == 0 {
                                "scale_to_zero"
                            } else {
                                "scale_downs"
                            });
                        }
                    }
                }
            });
        }

        Ok(Rc::new(Platform {
            config,
            app,
            containers,
            cluster,
            gateway,
            metrics,
            observer,
            billing,
            scaler,
            tracer,
            dispatcher,
            start: exec::now(),
            sampler_stop,
            originals,
        }))
    }

    /// Invoke the application's entry function with `payload`.
    pub async fn invoke(&self, payload: Vec<f32>) -> Result<Vec<f32>> {
        self.dispatcher.invoke(&self.app.entry.clone(), payload).await
    }

    /// Invoke an arbitrary function (targeted tests / custom clients).
    pub async fn invoke_function(&self, function: &str, payload: Vec<f32>) -> Result<Vec<f32>> {
        self.dispatcher.invoke(function, payload).await
    }

    /// [`Self::invoke_function`] under a live trace context from
    /// [`Platform::tracer`] (the workload driver owns begin/finish).
    pub async fn invoke_function_traced(
        &self,
        function: &str,
        payload: Vec<f32>,
        trace: Option<crate::trace::TraceCtx>,
    ) -> Result<Vec<f32>> {
        self.dispatcher.invoke_traced(function, payload, trace).await
    }

    /// Expected request payload length (f32 count).
    pub fn payload_len(&self) -> usize {
        self.dispatcher.payload_len()
    }

    /// Retained original image for `function` (the defusion redeploy
    /// source); None for functions the app does not define.
    pub fn original_image(&self, function: &str) -> Option<ImageId> {
        self.originals.get(function).copied()
    }

    /// Live group membership: the functions colocated with `function`
    /// (sorted; a single-element vec means the function is unfused).
    pub fn group_members(&self, function: &str) -> Vec<String> {
        match self.gateway.resolve(function) {
            Ok(inst) => {
                let mut v: Vec<String> =
                    inst.functions().iter().map(|(n, _)| n.clone()).collect();
                v.sort();
                v
            }
            Err(_) => Vec::new(),
        }
    }

    /// Distinct live fused instances (more than one hosted function).
    pub fn fused_groups(&self) -> Vec<Rc<Instance>> {
        fused_groups_of(&self.gateway)
    }

    /// Which node currently serves `function` (None when unrouted).
    pub fn node_of_function(&self, function: &str) -> Option<NodeId> {
        self.gateway.resolve(function).ok().and_then(|inst| self.cluster.node_of(inst.id()))
    }

    /// Simulation-core lane serving `function` under a sharded executor
    /// (0 when unrouted or unsharded).  Workload drivers pin each
    /// request's root task here (`exec::spawn_on`) so ingress enters on
    /// the lane of the node that will execute it.  Resolves through the
    /// set's primary replica — **never** the load-balanced `resolve`,
    /// which draws from the P2C RNG and would perturb seed streams.
    pub fn route_shard(&self, function: &str) -> usize {
        let shards = exec::shard_count();
        if shards <= 1 {
            return 0;
        }
        match self.gateway.resolve_set(function).ok().and_then(|set| set.primary()) {
            Some(inst) => self.cluster.shard_of(inst.id(), shards),
            None => 0,
        }
    }

    /// Final per-node RAM ledger: `(node id, live RAM MiB)` in node order —
    /// the cross-shard determinism artifact the fig9 shard-parity check
    /// compares bit-for-bit between 1-shard and N-shard runs.
    pub fn node_ram_ledger(&self) -> Vec<(u64, f64)> {
        self.cluster.nodes().iter().map(|n| (n.id().0, n.ram_mb())).collect()
    }

    /// Virtual time the platform finished deploying.
    pub fn start(&self) -> SimInstant {
        self.start
    }

    /// Milliseconds of virtual time since deployment finished.
    pub fn elapsed_ms(&self) -> f64 {
        exec::now().duration_since(self.start).as_secs_f64() * 1e3
    }

    /// Stop background tasks (sampler). The Merger loop ends when the
    /// platform (and its fusion sender) is dropped.
    pub fn shutdown(&self) {
        self.sampler_stop.set(true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::exec::run_virtual;

    fn cfg() -> PlatformConfig {
        PlatformConfig::tiny().with_compute(ComputeMode::Disabled)
    }

    #[test]
    fn deploy_boots_one_instance_per_function() {
        run_virtual(async {
            let p = Platform::deploy(apps::tree(), cfg()).await.unwrap();
            assert_eq!(p.containers.live_count(), 7);
            assert_eq!(p.gateway.len(), 7);
            assert_eq!(p.gateway.distinct_instances(), 7);
            p.shutdown();
        });
    }

    #[test]
    fn invoke_returns_response() {
        run_virtual(async {
            let p = Platform::deploy(apps::chain(3), cfg().vanilla()).await.unwrap();
            let payload = vec![0.5f32; p.payload_len()];
            let out = p.invoke(payload).await.unwrap();
            assert_eq!(out.len(), 64);
            assert!(out.iter().all(|v| v.is_finite()));
            p.shutdown();
        });
    }

    #[test]
    fn vanilla_never_merges() {
        run_virtual(async {
            let p = Platform::deploy(apps::chain(3), cfg().vanilla()).await.unwrap();
            for _ in 0..20 {
                let payload = vec![0.1f32; p.payload_len()];
                p.invoke(payload).await.unwrap();
            }
            exec::sleep_ms(30_000.0).await;
            assert_eq!(p.metrics.merges().len(), 0);
            assert_eq!(p.containers.live_count(), 3);
            p.shutdown();
        });
    }

    #[test]
    fn controller_attributes_group_ram_and_exposes_membership() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.latency.image_build_ms = 300.0;
            cfg.latency.boot_ms = 150.0;
            cfg.fusion.min_observations = 1;
            cfg.fusion.feedback_interval_ms = 1_000.0;
            let p = Platform::deploy(apps::chain(2), cfg).await.unwrap();
            for _ in 0..5 {
                let payload = vec![0.1f32; p.payload_len()];
                p.invoke(payload).await.unwrap();
                exec::sleep_ms(500.0).await;
            }
            exec::sleep_ms(20_000.0).await;
            assert_eq!(p.group_members("s0"), vec!["s0".to_string(), "s1".to_string()]);
            assert_eq!(p.fused_groups().len(), 1);
            assert!(p.original_image("s0").is_some());
            assert!(p.original_image("nope").is_none());
            // the controller attributed RAM to the fused group every tick
            let series = p.metrics.group_ram_for("s0+s1");
            assert!(!series.is_empty(), "no group RAM attribution recorded");
            assert!(series.iter().all(|s| s.ram_mb > 0.0));
            // ... and to each member: per-function shares sum to the group
            let fn_ram = p.metrics.fn_ram_series();
            assert!(!fn_ram.is_empty(), "no per-function RAM attribution recorded");
            let t0 = series[0].t_ms;
            let share_sum: f64 = fn_ram
                .iter()
                .filter(|s| s.t_ms == t0 && s.group == "s0+s1")
                .map(|s| s.ram_mb)
                .sum();
            assert!(
                (share_sum - series[0].ram_mb).abs() < 1e-9,
                "per-function shares {share_sum} != group RAM {}",
                series[0].ram_mb
            );
            // the handler emitted a latency sample per function invocation
            let fn_lat = p.metrics.fn_latency_series();
            assert!(fn_lat.iter().any(|s| s.function == "s0"));
            assert!(fn_lat.iter().any(|s| s.function == "s1"));
            assert!(fn_lat.iter().all(|s| s.handler_ms > 0.0));
            // healthy group under default policy: no splits, no evictions
            assert!(p.metrics.splits().is_empty());
            assert!(p.metrics.evicts().is_empty());
            // the quiescent topology satisfies the routing invariants
            routing_invariants(&p).unwrap();
            p.shutdown();
        });
    }

    #[test]
    fn cost_merge_policy_without_a_feedback_tick_is_rejected() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.fusion.merge_policy = MergePolicyKind::CostModel;
            cfg.fusion.feedback_interval_ms = 0.0;
            let err = Platform::deploy(apps::chain(2), cfg).await.unwrap_err();
            assert!(
                err.to_string().contains("feedback-interval-ms"),
                "unexpected error: {err}"
            );
        });
    }

    #[test]
    fn deploy_rejects_zero_replicas_max() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.scaling.replicas_max = 0;
            let err = Platform::deploy(apps::chain(2), cfg).await.unwrap_err();
            assert!(err.to_string().contains("--replicas-max 0"), "{err}");
        });
    }

    #[test]
    fn deploy_rejects_replica_floor_outside_the_ceiling() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.scaling.replicas_min = 0;
            let err = Platform::deploy(apps::chain(2), cfg).await.unwrap_err();
            assert!(err.to_string().contains("--replicas-min"), "{err}");

            let mut cfg = self::cfg();
            cfg.scaling.replicas_min = 5;
            cfg.scaling.replicas_max = 2;
            let err = Platform::deploy(apps::chain(2), cfg).await.unwrap_err();
            assert!(err.to_string().contains("--replicas-min 5"), "{err}");
        });
    }

    #[test]
    fn deploy_rejects_warm_pool_beyond_cluster_capacity() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.cluster.nodes = 2;
            cfg.cluster.node_capacity_mb = 100.0;
            cfg.scaling.warm_pool = 64; // 64 blanks cannot fit 200 MiB
            let err = Platform::deploy(apps::chain(2), cfg).await.unwrap_err();
            assert!(err.to_string().contains("--warm-pool 64"), "{err}");

            // ... while a pool the fleet can hold deploys fine
            let mut cfg = self::cfg();
            cfg.cluster.nodes = 2;
            cfg.cluster.node_capacity_mb = 1_000.0;
            cfg.scaling.warm_pool = 2;
            let p = Platform::deploy(apps::chain(2), cfg.vanilla()).await.unwrap();
            exec::sleep_ms(3_000.0).await;
            assert_eq!(p.scaler.pool_len(), 2);
            routing_invariants(&p).unwrap();
            p.shutdown();
        });
    }

    #[test]
    fn deploy_boots_the_replica_floor_per_function() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.scaling.replicas_min = 2;
            cfg.scaling.replicas_max = 2;
            let p = Platform::deploy(apps::chain(2), cfg.vanilla()).await.unwrap();
            assert_eq!(p.cluster.live_count(), 4, "2 functions x 2 replicas");
            assert_eq!(p.gateway.len(), 2);
            assert_eq!(p.gateway.distinct_instances(), 4);
            for f in ["s0", "s1"] {
                assert_eq!(p.gateway.resolve_set(f).unwrap().live_len(), 2);
            }
            let payload = vec![0.1f32; p.payload_len()];
            p.invoke(payload).await.unwrap();
            routing_invariants(&p).unwrap();
            p.shutdown();
        });
    }

    #[test]
    fn autoscaler_rides_a_burst_up_and_back_down_to_the_floor() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.scaling.replicas_max = 3;
            cfg.scaling.target_inflight = 1;
            cfg.scaling.concurrency = 1;
            cfg.scaling.scale_interval_ms = 200.0;
            cfg.scaling.warm_pool = 1;
            let p = Platform::deploy(apps::chain(2), cfg.vanilla()).await.unwrap();
            exec::sleep_ms(2_000.0).await; // warm blank becomes claimable

            // a burst far past one replica's single slot
            let mut handles = Vec::new();
            for _ in 0..12 {
                let p2 = Rc::clone(&p);
                handles.push(exec::spawn(async move {
                    let payload = vec![0.1f32; p2.payload_len()];
                    p2.invoke(payload).await.unwrap();
                }));
            }
            for h in handles {
                h.await;
            }
            assert!(
                p.metrics.counter("scale_ups") > 0,
                "burst must scale out: {}",
                p.metrics.counter("scale_ups")
            );
            assert!(
                p.metrics.counter("warm_pool_hits") > 0,
                "first scale-up should claim the warm blank"
            );
            assert!(p.gateway.resolve_set("s0").unwrap().live_len() > 1);

            // idle: the controller shrinks back to the one-replica floor
            exec::sleep_ms(30_000.0).await;
            assert_eq!(p.gateway.resolve_set("s0").unwrap().live_len(), 1);
            assert!(p.metrics.counter("scale_downs") > 0);
            exec::sleep_ms(2_000.0).await; // drained victims terminate
            routing_invariants(&p).unwrap();
            p.shutdown();
        });
    }

    #[test]
    fn cost_merge_policy_fuses_profitable_pair_from_real_signals() {
        run_virtual(async {
            // defusion OFF: the controller loop must still run purely for
            // the merge planner's window signals
            let mut cfg = cfg();
            cfg.latency.image_build_ms = 300.0;
            cfg.latency.boot_ms = 150.0;
            cfg.fusion.min_observations = 3;
            cfg.fusion.defusion = false;
            cfg.fusion.merge_policy = MergePolicyKind::CostModel;
            cfg.fusion.feedback_interval_ms = 1_000.0;
            let p = Platform::deploy(apps::chain(2), cfg).await.unwrap();
            // hot traffic: 20 rps keeps the caller blocked most of the wall
            // clock, so the predicted hop savings dwarf the RAM penalty
            let wl = crate::config::WorkloadConfig {
                requests: 100,
                rate_rps: 20.0,
                seed: 5,
                timeout_ms: 60_000.0,
            };
            crate::workload::run(Rc::clone(&p), wl).await.unwrap();
            exec::sleep_ms(20_000.0).await;
            assert_eq!(
                p.group_members("s0"),
                vec!["s0".to_string(), "s1".to_string()],
                "profitable hot pair must be admitted and fused"
            );
            // the planner's telemetry surfaced in the shared recorder
            let admissions = p.metrics.admissions();
            assert!(
                admissions.iter().any(|a| a.caller == "s0" && a.callee == "s1" && a.admitted),
                "no admitted evaluation recorded: {admissions:?}"
            );
            assert!(p.observer.admission_score("s0", "s1").is_finite());
            routing_invariants(&p).unwrap();
            p.shutdown();
        });
    }

    #[test]
    fn multi_node_affinity_colocates_the_sync_group() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.cluster.nodes = 3;
            cfg.cluster.placement = crate::config::PlacementPolicy::FusionAffinity;
            let p = Platform::deploy(apps::chain(4), cfg.vanilla()).await.unwrap();
            assert_eq!(p.cluster.node_count(), 3);
            assert_eq!(p.cluster.live_count(), 4);
            let home = p.node_of_function("s0").expect("s0 must have a node");
            for f in ["s1", "s2", "s3"] {
                assert_eq!(p.node_of_function(f), Some(home), "{f} off the group node");
            }
            // co-located chain: remote hops never cross nodes
            let payload = vec![0.1f32; p.payload_len()];
            p.invoke(payload).await.unwrap();
            assert_eq!(p.metrics.counter("cross_node_calls"), 0);
            routing_invariants(&p).unwrap();
            p.shutdown();
        });
    }

    #[test]
    fn multi_node_spread_pays_cross_node_hops_single_node_does_not() {
        run_virtual(async {
            let mut spread = cfg();
            spread.cluster.nodes = 3;
            spread.cluster.placement = crate::config::PlacementPolicy::Spread;
            let p = Platform::deploy(apps::chain(3), spread.vanilla()).await.unwrap();
            // 3 functions over 3 nodes: every interior hop crosses
            let nodes: std::collections::HashSet<_> =
                ["s0", "s1", "s2"].iter().map(|f| p.node_of_function(f).unwrap()).collect();
            assert_eq!(nodes.len(), 3, "spread must use all three nodes");
            let payload = vec![0.1f32; p.payload_len()];
            p.invoke(payload).await.unwrap();
            assert_eq!(p.metrics.counter("cross_node_calls"), 2, "s0->s1 and s1->s2");
            p.shutdown();

            let single = Platform::deploy(apps::chain(3), cfg().vanilla()).await.unwrap();
            let payload = vec![0.1f32; single.payload_len()];
            single.invoke(payload).await.unwrap();
            assert_eq!(single.metrics.counter("cross_node_calls"), 0);
            single.shutdown();
        });
    }

    #[test]
    fn cross_node_fusion_migrates_to_colocate_first() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.cluster.nodes = 2;
            cfg.cluster.placement = crate::config::PlacementPolicy::Spread;
            cfg.latency.image_build_ms = 300.0;
            cfg.latency.boot_ms = 150.0;
            cfg.fusion.min_observations = 1;
            let p = Platform::deploy(apps::chain(2), cfg).await.unwrap();
            assert_ne!(
                p.node_of_function("s0"),
                p.node_of_function("s1"),
                "spread must start the pair apart"
            );
            for _ in 0..5 {
                let payload = vec![0.1f32; p.payload_len()];
                p.invoke(payload).await.unwrap();
                exec::sleep_ms(500.0).await;
            }
            exec::sleep_ms(20_000.0).await;
            // fused into one instance on one node, via a co-location move
            assert_eq!(p.group_members("s0"), vec!["s0".to_string(), "s1".to_string()]);
            assert_eq!(p.gateway.distinct_instances(), 1);
            let migrations = p.metrics.migrations();
            assert_eq!(migrations.len(), 1, "{migrations:?}");
            assert_eq!(migrations[0].reason, "fusion_colocation");
            assert_eq!(p.metrics.counter("fusion_colocation_migrations"), 1);
            // post-fusion the whole chain is inline: no cross-node calls
            let before = p.metrics.counter("cross_node_calls");
            let payload = vec![0.1f32; p.payload_len()];
            p.invoke(payload).await.unwrap();
            assert_eq!(p.metrics.counter("cross_node_calls"), before);
            routing_invariants(&p).unwrap();
            p.shutdown();
        });
    }

    #[test]
    fn controller_records_per_node_ram_series() {
        run_virtual(async {
            let mut cfg = cfg();
            cfg.cluster.nodes = 2;
            cfg.cluster.node_capacity_mb = 500.0;
            let p = Platform::deploy(apps::chain(2), cfg.vanilla()).await.unwrap();
            exec::sleep_ms(5_000.0).await;
            let series = p.metrics.node_ram_series();
            assert!(series.iter().any(|s| s.node == crate::cluster::NodeId(0)));
            assert!(series.iter().any(|s| s.node == crate::cluster::NodeId(1)));
            assert!(series.iter().all(|s| s.capacity_mb == 500.0));
            // the per-node split sums to the platform series at each tick
            let total = p.metrics.ram_series();
            let t0 = total[0].t_ms;
            let node_sum: f64 = series.iter().filter(|s| s.t_ms == t0).map(|s| s.ram_mb).sum();
            assert!((node_sum - total[0].total_mb).abs() < 1e-9);
            p.shutdown();
        });
    }

    #[test]
    fn fusion_converges_chain_to_one_instance() {
        run_virtual(async {
            let p = Platform::deploy(apps::chain(3), cfg()).await.unwrap();
            for _ in 0..30 {
                let payload = vec![0.1f32; p.payload_len()];
                p.invoke(payload).await.unwrap();
                exec::sleep_ms(1_000.0).await;
            }
            exec::sleep_ms(60_000.0).await;
            assert!(p.metrics.merges().len() >= 2, "merges: {:?}", p.metrics.merges());
            assert_eq!(p.gateway.distinct_instances(), 1);
            // originals reclaimed
            assert_eq!(p.containers.live_count(), 1);
            p.shutdown();
        });
    }
}
