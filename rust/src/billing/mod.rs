//! Billing model — the paper's economic motivation, quantified.
//!
//! FaaS platforms bill per invocation: `duration x allocated memory` plus
//! a per-invocation fee (§2.3).  In composed applications a synchronous
//! call *double-bills*: the caller's instance is billed while it blocks on
//! the callee (Baldini et al.'s serverless trilemma).  Fusion eliminates
//! the inner invocations entirely — an inlined call is neither a billed
//! invocation nor a separately billed wait.
//!
//! The platform records one [`BillingEvent`] per **remote arrival** (what
//! a provider meters), with the serving instance's allocation.  Cost is
//! evaluated against a provider-style [`CostModel`].

use crate::metrics::Recorder;
use crate::util::intern::Sym;

/// One billed invocation.  The function is an interned [`Sym`] (ISSUE 5):
/// the handler records one event per remote arrival, so a `String` here
/// was one heap allocation per request.
#[derive(Debug, Clone, Copy)]
pub struct BillingEvent {
    /// virtual time the invocation completed (ms since the metrics epoch)
    pub t_ms: f64,
    pub function: Sym,
    /// billed duration (ms): dispatch + execution incl. blocking waits
    pub duration_ms: f64,
    /// memory allocation of the serving instance (GiB)
    pub alloc_gb: f64,
}

impl BillingEvent {
    pub fn gb_seconds(&self) -> f64 {
        self.duration_ms / 1e3 * self.alloc_gb
    }
}

/// Provider price sheet (defaults are AWS-Lambda-like list prices).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// $ per GiB-second of billed duration
    pub per_gb_second: f64,
    /// $ per million invocations
    pub per_million_invocations: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { per_gb_second: 0.0000166667, per_million_invocations: 0.20 }
    }
}

/// Aggregate bill over a run.
#[derive(Debug, Clone, Default)]
pub struct Bill {
    pub invocations: u64,
    pub gb_seconds: f64,
}

impl Bill {
    pub fn from_events(events: &[BillingEvent]) -> Bill {
        Bill {
            invocations: events.len() as u64,
            gb_seconds: events.iter().map(|e| e.gb_seconds()).sum(),
        }
    }

    /// Dollar cost under `model`.
    pub fn cost(&self, model: &CostModel) -> f64 {
        self.gb_seconds * model.per_gb_second
            + self.invocations as f64 / 1e6 * model.per_million_invocations
    }

    /// Cost per thousand application requests.
    pub fn cost_per_kreq(&self, model: &CostModel, requests: u64) -> f64 {
        if requests == 0 {
            return f64::NAN;
        }
        self.cost(model) * 1e3 / requests as f64
    }
}

/// Recorder extension: billing events ride the counters-free side channel.
#[derive(Clone, Default)]
pub struct BillingLedger {
    events: std::rc::Rc<std::cell::RefCell<Vec<BillingEvent>>>,
    /// retention horizon (ms); 0 = keep every event (seed behavior).  Set
    /// by [`BillingLedger::windowed`] so a million-request run's ledger is
    /// bounded like the windowed metrics recorder — one event per remote
    /// arrival is otherwise O(requests) memory.
    retention_ms: std::rc::Rc<std::cell::Cell<f64>>,
}

impl BillingLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bounded ledger: events older than `retention_ms` behind the newest
    /// event are pruned (amortized — the buffer spans at most twice the
    /// horizon).  Trailing-window queries inside the horizon are unchanged;
    /// whole-run aggregates ([`Self::bill`], [`Self::gb_seconds_for`])
    /// cover only the retained span.
    pub fn windowed(retention_ms: f64) -> Self {
        let ledger = Self::default();
        ledger.retention_ms.set(retention_ms.max(0.0));
        ledger
    }

    pub fn record(&self, event: BillingEvent) {
        let mut events = self.events.borrow_mut();
        let retention = self.retention_ms.get();
        if retention > 0.0 {
            if let Some(first) = events.first() {
                if event.t_ms - first.t_ms > 2.0 * retention {
                    let cutoff = event.t_ms - retention;
                    let cut = events.partition_point(|e| e.t_ms < cutoff);
                    events.drain(..cut);
                }
            }
        }
        events.push(event);
    }

    /// Approximate ledger heap footprint (bytes) — included in the FIG9
    /// bounded-telemetry self-check alongside `Recorder::approx_bytes`.
    pub fn approx_bytes(&self) -> usize {
        self.events.borrow().capacity() * std::mem::size_of::<BillingEvent>()
    }

    pub fn events(&self) -> Vec<BillingEvent> {
        self.events.borrow().clone()
    }

    pub fn bill(&self) -> Bill {
        Bill::from_events(&self.events.borrow())
    }

    /// Billed GiB-seconds attributed to one function name (lookup, not
    /// intern: a query for an unknown name must not grow the leaked table).
    pub fn gb_seconds_for(&self, function: &str) -> f64 {
        match Sym::lookup(function) {
            Some(sym) => self.gb_seconds_for_sym(sym),
            None => 0.0,
        }
    }

    pub fn gb_seconds_for_sym(&self, function: Sym) -> f64 {
        self.events
            .borrow()
            .iter()
            .filter(|e| e.function == function)
            .map(|e| e.gb_seconds())
            .sum()
    }

    /// Billed GiB-seconds attributed to `function` by invocations that
    /// completed inside `[from_ms, to_ms)` — the trailing-window signal the
    /// defusion cost model scores groups with.
    ///
    /// Events are recorded at completion time, so the ledger is sorted by
    /// `t_ms`; a binary search bounds the controller's per-tick work to the
    /// trailing window instead of the whole run's history.
    pub fn gb_seconds_window(&self, function: &str, from_ms: f64, to_ms: f64) -> f64 {
        match Sym::lookup(function) {
            Some(sym) => self.gb_seconds_window_sym(sym, from_ms, to_ms),
            None => 0.0,
        }
    }

    /// [`Self::gb_seconds_window`] for callers already holding a [`Sym`]
    /// (the controller tick).
    pub fn gb_seconds_window_sym(&self, function: Sym, from_ms: f64, to_ms: f64) -> f64 {
        let borrowed = self.events.borrow();
        let events: &[BillingEvent] = &borrowed;
        let start = events.partition_point(|e| e.t_ms < from_ms);
        events[start..]
            .iter()
            .take_while(|e| e.t_ms < to_ms)
            .filter(|e| e.function == function)
            .map(|e| e.gb_seconds())
            .sum()
    }

    /// Billed wall milliseconds attributed to `function` by invocations
    /// completing inside `[from_ms, to_ms)` — duration *including* blocked
    /// sync waits.  Together with the handler's windowed self-time this
    /// yields the caller's double-billed blocked time, the merge planner's
    /// hop-savings signal (see `fusion::cost::CostModel::predict_merge`).
    pub fn billed_ms_window(&self, function: &str, from_ms: f64, to_ms: f64) -> f64 {
        match Sym::lookup(function) {
            Some(sym) => self.billed_ms_window_sym(sym, from_ms, to_ms),
            None => 0.0,
        }
    }

    /// [`Self::billed_ms_window`] for callers already holding a [`Sym`].
    pub fn billed_ms_window_sym(&self, function: Sym, from_ms: f64, to_ms: f64) -> f64 {
        let borrowed = self.events.borrow();
        let events: &[BillingEvent] = &borrowed;
        let start = events.partition_point(|e| e.t_ms < from_ms);
        events[start..]
            .iter()
            .take_while(|e| e.t_ms < to_ms)
            .filter(|e| e.function == function)
            .map(|e| e.duration_ms)
            .sum()
    }

    pub fn attach_summary(&self, metrics: &Recorder) {
        let bill = self.bill();
        for _ in 0..bill.invocations {
            metrics.bump("billed_invocations");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t_ms: f64, function: &str, duration_ms: f64, alloc_gb: f64) -> BillingEvent {
        BillingEvent { t_ms, function: Sym::intern(function), duration_ms, alloc_gb }
    }

    #[test]
    fn gb_seconds_math() {
        let e = ev(0.0, "f", 2_000.0, 0.5);
        assert!((e.gb_seconds() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bill_cost() {
        let events = vec![ev(1.0, "a", 1_000.0, 1.0), ev(2.0, "b", 500.0, 2.0)];
        let bill = Bill::from_events(&events);
        assert_eq!(bill.invocations, 2);
        assert!((bill.gb_seconds - 2.0).abs() < 1e-12);
        let m = CostModel::default();
        let expected = 2.0 * m.per_gb_second + 2.0 / 1e6 * m.per_million_invocations;
        assert!((bill.cost(&m) - expected).abs() < 1e-15);
        assert!(bill.cost_per_kreq(&m, 0).is_nan());
    }

    #[test]
    fn ledger_per_function_attribution() {
        let l = BillingLedger::new();
        l.record(ev(10.0, "a", 1_000.0, 1.0));
        l.record(ev(20.0, "a", 1_000.0, 1.0));
        l.record(ev(30.0, "b", 1_000.0, 0.25));
        assert!((l.gb_seconds_for("a") - 2.0).abs() < 1e-12);
        assert!((l.gb_seconds_for("b") - 0.25).abs() < 1e-12);
        assert_eq!(l.bill().invocations, 3);
    }

    #[test]
    fn windowed_ledger_prunes_but_keeps_the_horizon() {
        let l = BillingLedger::windowed(1_000.0);
        for i in 0..10_000u64 {
            l.record(ev(i as f64, "a", 10.0, 1.0));
        }
        let retained = l.events();
        // bounded: at most ~2x the horizon (2000 events at 1 per ms)
        assert!(retained.len() <= 2_001, "retained {} events", retained.len());
        // everything inside one horizon behind the newest event survives
        let newest = retained.last().unwrap().t_ms;
        assert_eq!(newest, 9_999.0);
        assert!(retained.first().unwrap().t_ms <= newest - 1_000.0);
        // trailing-window queries are unaffected
        assert!((l.billed_ms_window("a", 9_000.0, 10_000.0) - 10_000.0).abs() < 1e-9);
        assert!(l.approx_bytes() > 0);
        // unbounded default keeps everything
        let full = BillingLedger::new();
        for i in 0..5_000u64 {
            full.record(ev(i as f64, "a", 10.0, 1.0));
        }
        assert_eq!(full.events().len(), 5_000);
    }

    #[test]
    fn windowed_attribution_slices_by_completion_time() {
        let l = BillingLedger::new();
        l.record(ev(10.0, "a", 1_000.0, 1.0));
        l.record(ev(50.0, "a", 1_000.0, 1.0));
        l.record(ev(50.0, "b", 1_000.0, 1.0));
        l.record(ev(90.0, "a", 1_000.0, 1.0));
        assert!((l.gb_seconds_window("a", 40.0, 80.0) - 1.0).abs() < 1e-12);
        assert!((l.gb_seconds_window("a", 0.0, 100.0) - 3.0).abs() < 1e-12);
        // window bounds are [from, to)
        assert!((l.gb_seconds_window("a", 0.0, 90.0) - 2.0).abs() < 1e-12);
        assert_eq!(l.gb_seconds_window("ghost", 0.0, 100.0), 0.0);
    }

    #[test]
    fn windowed_billed_duration_slices_by_completion_time() {
        let l = BillingLedger::new();
        l.record(ev(10.0, "a", 100.0, 1.0));
        l.record(ev(50.0, "a", 300.0, 0.5));
        l.record(ev(50.0, "b", 700.0, 1.0));
        l.record(ev(90.0, "a", 500.0, 1.0));
        assert!((l.billed_ms_window("a", 0.0, 100.0) - 900.0).abs() < 1e-12);
        // [from, to) bounds; alloc does not affect the duration sum
        assert!((l.billed_ms_window("a", 40.0, 90.0) - 300.0).abs() < 1e-12);
        assert!((l.billed_ms_window("b", 0.0, 100.0) - 700.0).abs() < 1e-12);
        assert_eq!(l.billed_ms_window("ghost", 0.0, 100.0), 0.0);
    }
}
