//! Request-level span tracing with exact critical-path latency attribution
//! (ISSUE 9).
//!
//! The handler prices every component of a request's end-to-end latency —
//! gateway admission, service indirection, network traversal, cross-node
//! surcharge, serialization, cold-start waits, concurrency-gate queueing,
//! dispatch, inline hops, handler self-time — and then charges them as
//! opaque `sleep_ms` timers.  This module records that decomposition as a
//! per-request **span tree** so the platform can answer "where did the
//! latency go?" mechanically.
//!
//! Because time is virtual, the decomposition is *exact*: every
//! time-advancing await on a request's path is bracketed by spans that
//! tile their parent frame with no gaps, so a trace's critical path sums
//! **bit-for-bit** to the `LatencySample` the recorder keeps for the same
//! request (the conservation contract; see [`verify`]).
//!
//! Design constraints inherited from ISSUE 5's telemetry work:
//!
//! * **Zero cost when off.** `--trace-sample 0` (the seed default) builds
//!   a [`Tracer`] with no inner state; every call site is an `Option`
//!   check and the resolved-request hot path performs zero additional
//!   allocations (asserted by `benches/trace_overhead.rs`).
//! * **Bounded when on.** Span buffers are pooled and reused across
//!   requests; retained traces live in a ring capped at
//!   `--trace-max` entries.  [`Tracer::approx_bytes`] is the
//!   recorder-style byte bound `figure9` budgets.
//! * **Deterministic.** Retention draws from a dedicated seeded RNG (the
//!   fabric streams are untouched), so a pinned seed retains the same
//!   traces every run — and an enabled tracer never perturbs the
//!   schedule, a property `figure9` checks by verdict-transcript parity
//!   against an untraced twin.
//!
//! Sampling is 1-in-N by seeded draw, plus two always-retain classes:
//! **dropped** requests (timeouts and errors — the traces operators
//! actually need) and the **window-slowest-so-far** request of each
//! aggregation window (an online approximation of per-window slowest:
//! the first and every record-breaking request of a window is kept).

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;

use crate::config::TraceParams;
use crate::exec::{self, SimInstant};
use crate::util::intern::Sym;
use crate::util::rng::Rng;

/// Sentinel parent index for the root span.
pub const NO_PARENT: u32 = u32::MAX;

/// Sentinel end for a span that was opened but never closed (the request
/// failed or timed out mid-flight); finalization clamps it.
const OPEN_END: u64 = u64::MAX;

/// Hard cap on spans per trace — a runaway fan-out stops recording (and
/// the trace is marked truncated, exempting it from conservation) instead
/// of growing without bound.
pub const MAX_SPANS_PER_TRACE: usize = 8_192;

/// What a span's interval was spent on.  Leaf kinds mirror the components
/// the handler/gateway/replica path prices; container kinds (`Request`,
/// `Invoke`, `Exec`, `Join`) structure the tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// root: one per sampled request, spanning the whole e2e interval
    Request,
    /// one remote invocation frame (gateway -> ... -> response)
    Invoke,
    /// one handler execution frame (dispatch/inline + body + sync joins)
    Exec,
    /// caller blocked on one synchronous child call
    Join,
    /// client/caller -> gateway admission + route lookup
    Gateway,
    /// Kubernetes Service VIP indirection (zero on tiny)
    ServiceIndirection,
    /// instance-to-instance network traversal (request or response leg)
    Network,
    /// east-west surcharge for a hop crossing node boundaries
    CrossNode,
    /// payload/response (de)serialization
    Serialize,
    /// queued behind a booting instance (cold start)
    ColdWait,
    /// queued on the replica's concurrency gate
    GateQueue,
    /// scale-from-zero revival and fuse/split/migration cutover retries
    CutoverStall,
    /// handler dispatch shim (remote arrivals only)
    Dispatch,
    /// fused same-process call hop
    Inline,
    /// handler self-time: compute body + calibrated busy term
    SelfTime,
}

impl SpanKind {
    /// Stable lowercase name used in CSV and Chrome-trace exports.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Invoke => "invoke",
            SpanKind::Exec => "exec",
            SpanKind::Join => "join",
            SpanKind::Gateway => "gateway",
            SpanKind::ServiceIndirection => "service_indirection",
            SpanKind::Network => "network",
            SpanKind::CrossNode => "cross_node",
            SpanKind::Serialize => "serialize",
            SpanKind::ColdWait => "cold_wait",
            SpanKind::GateQueue => "gate_queue",
            SpanKind::CutoverStall => "cutover_stall",
            SpanKind::Dispatch => "dispatch",
            SpanKind::Inline => "inline",
            SpanKind::SelfTime => "self",
        }
    }

    /// Leaf component kinds — the ones the breakdown ledger aggregates.
    /// Container kinds (`Request`/`Invoke`/`Exec`/`Join`) only structure
    /// the tree; counting them would double-charge their contents.
    pub fn is_component(self) -> bool {
        !matches!(
            self,
            SpanKind::Request | SpanKind::Invoke | SpanKind::Exec | SpanKind::Join
        )
    }
}

/// One node of a request's span tree.  Intervals are virtual-clock
/// nanoseconds (the executor's native unit), so sums are exact integer
/// arithmetic and the conservation contract is bit-for-bit.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub kind: SpanKind,
    /// function the interval is attributed to
    pub function: Sym,
    /// index of the parent span in the trace (`NO_PARENT` for the root)
    pub parent: u32,
    /// critical-path segment: the crit children of any span tile its
    /// interval exactly (no gaps, no overlap)
    pub crit: bool,
    pub start_ns: u64,
    pub end_ns: u64,
}

impl Span {
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// Why a finished trace was retained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetainReason {
    /// seeded 1-in-N draw
    Sampled,
    /// slowest-so-far in its aggregation window
    WindowSlowest,
    /// the request failed or timed out — always retained
    Dropped,
}

impl RetainReason {
    pub fn name(self) -> &'static str {
        match self {
            RetainReason::Sampled => "sampled",
            RetainReason::WindowSlowest => "window_slowest",
            RetainReason::Dropped => "dropped",
        }
    }
}

/// One retained request trace.
#[derive(Debug, Clone)]
pub struct Trace {
    /// monotonic per-tracer sequence number (assigned at begin)
    pub seq: u64,
    /// arrival time (recorder-relative ms), the aggregation-window key
    pub t_ms: f64,
    /// entry function of the request
    pub function: Sym,
    /// recorded e2e latency (ms); NaN for dropped requests
    pub latency_ms: f64,
    /// the request failed or timed out (partial span tree, no
    /// conservation claim)
    pub dropped: bool,
    /// span recording hit [`MAX_SPANS_PER_TRACE`] (no conservation claim)
    pub truncated: bool,
    /// the critical path summed bit-for-bit to `latency_ms`
    pub conserved: bool,
    pub reason: RetainReason,
    pub spans: Vec<Span>,
}

/// Copy handle threading a live trace through the dispatcher: which slot
/// the request records into and which span new children attach to.
#[derive(Debug, Clone, Copy)]
pub struct TraceCtx {
    tok: u32,
    span: u32,
}

/// Handle to one open critical-path segment.
#[derive(Debug, Clone, Copy)]
pub struct SegRef {
    tok: u32,
    span: u32,
}

/// In-flight per-request recording state (pooled and reused).
struct Slot {
    seq: u64,
    t_ms: f64,
    function: Sym,
    truncated: bool,
    spans: Vec<Span>,
}

struct TracerInner {
    sample_every: u64,
    max_traces: usize,
    window_ms: f64,
    rng: RefCell<Rng>,
    slots: RefCell<Vec<Slot>>,
    free: RefCell<Vec<u32>>,
    retained: RefCell<VecDeque<Trace>>,
    /// scratch per-span crit-child sums for the finish-time conservation
    /// check (reused; zero steady-state allocation)
    scratch: RefCell<Vec<u64>>,
    next_seq: Cell<u64>,
    started: Cell<u64>,
    finished: Cell<u64>,
    dropped: Cell<u64>,
    retained_total: Cell<u64>,
    conservation_violations: Cell<u64>,
    /// slowest-so-far state of the current aggregation window
    window_index: Cell<i64>,
    window_max_ms: Cell<f64>,
}

/// Deterministic, bounded span tracer.  Cheaply clonable; a disabled
/// tracer (`sample_every == 0`) carries no state and every operation is a
/// no-op.
#[derive(Clone)]
pub struct Tracer {
    inner: Option<Rc<TracerInner>>,
}

impl Tracer {
    /// Build from config; `params.sample_every == 0` yields the disabled
    /// (zero-cost) tracer.
    pub fn new(params: &TraceParams, seed: u64) -> Self {
        if params.sample_every == 0 {
            return Tracer::disabled();
        }
        Tracer {
            inner: Some(Rc::new(TracerInner {
                sample_every: params.sample_every,
                max_traces: params.max_traces.max(1),
                window_ms: if params.window_ms > 0.0 { params.window_ms } else { 1_000.0 },
                rng: RefCell::new(Rng::new(seed ^ 0x7ACE_7ACE)),
                slots: RefCell::new(Vec::new()),
                free: RefCell::new(Vec::new()),
                retained: RefCell::new(VecDeque::new()),
                scratch: RefCell::new(Vec::new()),
                next_seq: Cell::new(0),
                started: Cell::new(0),
                finished: Cell::new(0),
                dropped: Cell::new(0),
                retained_total: Cell::new(0),
                conservation_violations: Cell::new(0),
                window_index: Cell::new(i64::MIN),
                window_max_ms: Cell::new(f64::NEG_INFINITY),
            })),
        }
    }

    /// The zero-cost tracer: every call is an `Option` check and nothing
    /// else — no allocation, no RNG, no clock reads.
    pub fn disabled() -> Self {
        Tracer { inner: None }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Start recording one request arriving at `t_ms` (recorder-relative).
    /// Returns `None` when disabled; the returned context's parent is the
    /// root `Request` span.
    pub fn begin_request(&self, function: Sym, t_ms: f64) -> Option<TraceCtx> {
        let inner = self.inner.as_ref()?;
        let seq = inner.next_seq.get();
        inner.next_seq.set(seq + 1);
        inner.started.set(inner.started.get() + 1);
        let root = Span {
            kind: SpanKind::Request,
            function,
            parent: NO_PARENT,
            crit: false,
            start_ns: exec::now().0,
            end_ns: OPEN_END,
        };
        let mut slots = inner.slots.borrow_mut();
        let tok = match inner.free.borrow_mut().pop() {
            Some(tok) => {
                let slot = &mut slots[tok as usize];
                slot.seq = seq;
                slot.t_ms = t_ms;
                slot.function = function;
                slot.truncated = false;
                slot.spans.clear();
                slot.spans.push(root);
                tok
            }
            None => {
                slots.push(Slot {
                    seq,
                    t_ms,
                    function,
                    truncated: false,
                    spans: vec![root],
                });
                (slots.len() - 1) as u32
            }
        };
        Some(TraceCtx { tok, span: 0 })
    }

    fn push_span(inner: &TracerInner, tok: u32, span: Span) -> u32 {
        let mut slots = inner.slots.borrow_mut();
        let slot = &mut slots[tok as usize];
        if slot.spans.len() >= MAX_SPANS_PER_TRACE {
            slot.truncated = true;
            return u32::MAX;
        }
        slot.spans.push(span);
        (slot.spans.len() - 1) as u32
    }

    /// Open a container frame (`Invoke`/`Exec`) under `ctx`; children of
    /// the returned context attach to the new frame.  `crit` marks the
    /// frame as a critical-path segment of its parent (true when the
    /// caller awaits it inline rather than through a `Join`).
    pub fn open_frame(
        &self,
        ctx: Option<TraceCtx>,
        kind: SpanKind,
        function: Sym,
        crit: bool,
    ) -> Option<TraceCtx> {
        let inner = self.inner.as_ref()?;
        let ctx = ctx?;
        let idx = Self::push_span(
            inner,
            ctx.tok,
            Span {
                kind,
                function,
                parent: ctx.span,
                crit,
                start_ns: exec::now().0,
                end_ns: OPEN_END,
            },
        );
        if idx == u32::MAX {
            return None;
        }
        Some(TraceCtx { tok: ctx.tok, span: idx })
    }

    /// Close a frame opened with [`Self::open_frame`].
    pub fn close_frame(&self, ctx: Option<TraceCtx>) {
        let (Some(inner), Some(ctx)) = (self.inner.as_ref(), ctx) else {
            return;
        };
        let now = exec::now().0;
        let mut slots = inner.slots.borrow_mut();
        slots[ctx.tok as usize].spans[ctx.span as usize].end_ns = now;
    }

    /// Open a critical-path segment (cold wait, gate queue, join, ...)
    /// under `ctx`, starting now.
    pub fn start_seg(
        &self,
        ctx: Option<TraceCtx>,
        kind: SpanKind,
        function: Sym,
    ) -> Option<SegRef> {
        let inner = self.inner.as_ref()?;
        let ctx = ctx?;
        let idx = Self::push_span(
            inner,
            ctx.tok,
            Span {
                kind,
                function,
                parent: ctx.span,
                crit: true,
                start_ns: exec::now().0,
                end_ns: OPEN_END,
            },
        );
        if idx == u32::MAX {
            return None;
        }
        Some(SegRef { tok: ctx.tok, span: idx })
    }

    /// Close a segment opened with [`Self::start_seg`].  Zero-length
    /// segments (no virtual time passed) are removed again when they are
    /// the newest span — the common no-wait case stays span-free.
    pub fn end_seg(&self, seg: Option<SegRef>) {
        let (Some(inner), Some(seg)) = (self.inner.as_ref(), seg) else {
            return;
        };
        let now = exec::now().0;
        let mut slots = inner.slots.borrow_mut();
        let spans = &mut slots[seg.tok as usize].spans;
        let span = &mut spans[seg.span as usize];
        span.end_ns = now;
        if span.start_ns == now && seg.span as usize == spans.len() - 1 {
            spans.pop();
        }
    }

    /// Record the component breakdown of one already-charged interval
    /// `[start, end]`: consecutive critical sub-spans partition the
    /// interval in `parts` order, each sized by its modeled cost in ms
    /// (converted with the executor's own ms→ns rule); the last non-zero
    /// part absorbs the sub-nanosecond conversion remainder so the
    /// partition tiles the measured interval exactly.  Zero-cost parts
    /// are skipped.
    pub fn add_parts(
        &self,
        ctx: Option<TraceCtx>,
        start: SimInstant,
        end: SimInstant,
        function: Sym,
        parts: &[(SpanKind, f64)],
    ) {
        let (Some(inner), Some(ctx)) = (self.inner.as_ref(), ctx) else {
            return;
        };
        let end_ns = end.0.max(start.0);
        let mut cursor = start.0;
        let last_nonzero = parts.iter().rposition(|(_, ms)| *ms > 0.0);
        for (i, (kind, ms)) in parts.iter().enumerate() {
            if *ms <= 0.0 {
                continue;
            }
            // same conversion as exec::sleep_ms, clamped into the interval
            let span_end = if Some(i) == last_nonzero {
                end_ns
            } else {
                (cursor + (*ms * 1e6) as u64).min(end_ns)
            };
            Self::push_span(
                inner,
                ctx.tok,
                Span {
                    kind: *kind,
                    function,
                    parent: ctx.span,
                    crit: true,
                    start_ns: cursor,
                    end_ns: span_end,
                },
            );
            cursor = span_end;
        }
    }

    /// Finish a successful request: close the root, run the conservation
    /// check against the recorded `latency_ms`, and decide retention
    /// (seeded 1-in-N or slowest-so-far in the window).
    pub fn finish_ok(&self, ctx: Option<TraceCtx>, latency_ms: f64) {
        let (Some(inner), Some(ctx)) = (self.inner.as_ref(), ctx) else {
            return;
        };
        inner.finished.set(inner.finished.get() + 1);
        let conserved = {
            let mut slots = inner.slots.borrow_mut();
            let slot = &mut slots[ctx.tok as usize];
            let now = exec::now().0;
            for s in slot.spans.iter_mut() {
                if s.end_ns == OPEN_END {
                    s.end_ns = now;
                }
            }
            let ok = !slot.truncated && conservation_holds(&slot.spans, latency_ms, &inner.scratch);
            if !ok {
                inner
                    .conservation_violations
                    .set(inner.conservation_violations.get() + 1);
            }
            ok
        };
        // retention: seeded 1-in-N ...
        let sampled = inner.rng.borrow_mut().below(inner.sample_every) == 0;
        // ... plus the slowest-so-far request of each aggregation window
        let t_ms = inner.slots.borrow()[ctx.tok as usize].t_ms;
        let window = (t_ms / inner.window_ms).floor() as i64;
        let slowest = if window != inner.window_index.get() {
            inner.window_index.set(window);
            inner.window_max_ms.set(latency_ms);
            true
        } else if latency_ms > inner.window_max_ms.get() {
            inner.window_max_ms.set(latency_ms);
            true
        } else {
            false
        };
        if sampled || slowest {
            let reason =
                if sampled { RetainReason::Sampled } else { RetainReason::WindowSlowest };
            self.retain(ctx.tok, latency_ms, false, conserved, reason);
        } else {
            self.release(ctx.tok);
        }
    }

    /// Finish a failed or timed-out request: the (partial) trace is
    /// always retained — these are the traces operators need most.
    pub fn finish_dropped(&self, ctx: Option<TraceCtx>) {
        let (Some(inner), Some(ctx)) = (self.inner.as_ref(), ctx) else {
            return;
        };
        inner.finished.set(inner.finished.get() + 1);
        inner.dropped.set(inner.dropped.get() + 1);
        {
            let mut slots = inner.slots.borrow_mut();
            let now = exec::now().0;
            for s in slots[ctx.tok as usize].spans.iter_mut() {
                if s.end_ns == OPEN_END {
                    s.end_ns = now;
                }
            }
        }
        self.retain(ctx.tok, f64::NAN, true, false, RetainReason::Dropped);
    }

    fn retain(&self, tok: u32, latency_ms: f64, dropped: bool, conserved: bool, reason: RetainReason) {
        let inner = self.inner.as_ref().expect("retain on disabled tracer");
        let trace = {
            let mut slots = inner.slots.borrow_mut();
            let slot = &mut slots[tok as usize];
            Trace {
                seq: slot.seq,
                t_ms: slot.t_ms,
                function: slot.function,
                latency_ms,
                dropped,
                truncated: slot.truncated,
                conserved,
                reason,
                spans: std::mem::take(&mut slot.spans),
            }
        };
        let mut retained = inner.retained.borrow_mut();
        if retained.len() >= inner.max_traces {
            retained.pop_front();
        }
        retained.push_back(trace);
        inner.retained_total.set(inner.retained_total.get() + 1);
        inner.free.borrow_mut().push(tok);
    }

    fn release(&self, tok: u32) {
        let inner = self.inner.as_ref().expect("release on disabled tracer");
        inner.slots.borrow_mut()[tok as usize].spans.clear();
        inner.free.borrow_mut().push(tok);
    }

    /// Requests whose recording began.
    pub fn started(&self) -> u64 {
        self.inner.as_ref().map(|i| i.started.get()).unwrap_or(0)
    }

    /// Requests whose recording finished (ok or dropped).
    pub fn finished(&self) -> u64 {
        self.inner.as_ref().map(|i| i.finished.get()).unwrap_or(0)
    }

    /// Traces retained over the run's lifetime (the ring may since have
    /// evicted some).
    pub fn retained_total(&self) -> u64 {
        self.inner.as_ref().map(|i| i.retained_total.get()).unwrap_or(0)
    }

    /// Finished traces whose critical path did **not** sum bit-for-bit to
    /// the recorded latency.  Always 0 unless the handler grew an
    /// unbracketed await — the self-check `figure12` and the property
    /// suite pin.
    pub fn conservation_violations(&self) -> u64 {
        self.inner.as_ref().map(|i| i.conservation_violations.get()).unwrap_or(0)
    }

    /// Snapshot of the retained-trace ring (oldest first).
    pub fn snapshot(&self) -> Vec<Trace> {
        match &self.inner {
            Some(i) => i.retained.borrow().iter().cloned().collect(),
            None => Vec::new(),
        }
    }

    /// Approximate tracer heap footprint (bytes): pooled slot buffers plus
    /// the retained ring — the `trace_bytes` bound `figure9` records.
    pub fn approx_bytes(&self) -> usize {
        use std::mem::size_of;
        let Some(inner) = self.inner.as_ref() else {
            return 0;
        };
        let slots = inner.slots.borrow();
        let mut b = slots.capacity() * size_of::<Slot>();
        b += slots.iter().map(|s| s.spans.capacity() * size_of::<Span>()).sum::<usize>();
        b += inner.free.borrow().capacity() * size_of::<u32>();
        let retained = inner.retained.borrow();
        b += retained.capacity() * size_of::<Trace>();
        b += retained.iter().map(|t| t.spans.capacity() * size_of::<Span>()).sum::<usize>();
        b += inner.scratch.borrow().capacity() * size_of::<u64>();
        b
    }

    /// Per-window latency-breakdown ledger over the retained traces, in
    /// CSV form: `window_ms,function,component,total_ms,share_of_e2e`.
    ///
    /// One row per (aggregation window, entry function, component kind):
    /// `total_ms` sums every component span of that kind across the
    /// window's retained traces for that entry route; `share_of_e2e`
    /// divides by the same traces' summed end-to-end time.  Shares of one
    /// route's rows sum to 1 for sequential call chains; under concurrent
    /// sync fan-out component *work* can exceed e2e *wall* time, so
    /// shares may sum past 1 (work vs span, as in any trace analytics).
    /// Dropped (partial) traces are excluded.
    pub fn latency_breakdown_csv(&self) -> String {
        use std::collections::BTreeMap;
        let mut out = String::from("window_ms,function,component,total_ms,share_of_e2e\n");
        let Some(inner) = self.inner.as_ref() else {
            return out;
        };
        // (window, entry route, kind name) -> summed ns
        let mut by_component: BTreeMap<(i64, Sym, &'static str), u128> = BTreeMap::new();
        let mut e2e: BTreeMap<(i64, Sym), u128> = BTreeMap::new();
        for trace in inner.retained.borrow().iter() {
            if trace.dropped {
                continue;
            }
            let window = (trace.t_ms / inner.window_ms).floor() as i64;
            let route = trace.function;
            let root_ns = trace.spans.first().map(|s| s.duration_ns()).unwrap_or(0);
            *e2e.entry((window, route)).or_insert(0) += root_ns as u128;
            for span in &trace.spans {
                if span.kind.is_component() {
                    *by_component.entry((window, route, span.kind.name())).or_insert(0) +=
                        span.duration_ns() as u128;
                }
            }
        }
        for ((window, route, component), ns) in &by_component {
            let total = *e2e.get(&(*window, *route)).unwrap_or(&0);
            let share = if total > 0 { *ns as f64 / total as f64 } else { f64::NAN };
            out.push_str(&format!(
                "{},{},{},{:.6},{:.6}\n",
                *window as f64 * inner.window_ms,
                route.as_str(),
                component,
                *ns as f64 / 1e6,
                share
            ));
        }
        out
    }

    /// Retained traces as Chrome trace-event JSON (load in
    /// `chrome://tracing` / Perfetto).  One `tid` per request; `ts`/`dur`
    /// in microseconds of virtual time.
    pub fn chrome_trace_json(&self) -> String {
        let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        let mut first = true;
        if let Some(inner) = self.inner.as_ref() {
            for trace in inner.retained.borrow().iter() {
                for span in &trace.spans {
                    if !first {
                        out.push(',');
                    }
                    first = false;
                    out.push_str(&format!(
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{:.3},\
                         \"dur\":{:.3},\"pid\":0,\"tid\":{},\"args\":{{\"function\":\"{}\",\
                         \"crit\":{},\"reason\":\"{}\"}}}}",
                        span.kind.name(),
                        if span.crit { "crit" } else { "frame" },
                        span.start_ns as f64 / 1e3,
                        span.duration_ns() as f64 / 1e3,
                        trace.seq,
                        span.function.as_str(),
                        span.crit,
                        trace.reason.name(),
                    ));
                }
            }
        }
        out.push_str("]}");
        out
    }
}

/// The finish-time conservation check: every span with critical children
/// must be tiled by them exactly, and the root's interval must convert to
/// the recorded latency bit-for-bit (same nanos→ms arithmetic as the
/// workload's measurement).
fn conservation_holds(spans: &[Span], latency_ms: f64, scratch: &RefCell<Vec<u64>>) -> bool {
    let Some(root) = spans.first() else {
        return false;
    };
    let root_ms = std::time::Duration::from_nanos(root.duration_ns()).as_secs_f64() * 1e3;
    if root_ms.to_bits() != latency_ms.to_bits() {
        return false;
    }
    let mut sums = scratch.borrow_mut();
    sums.clear();
    sums.resize(spans.len(), 0);
    let mut has_crit_child = vec![false; spans.len()];
    for span in spans {
        if span.crit && span.parent != NO_PARENT {
            sums[span.parent as usize] += span.duration_ns();
            has_crit_child[span.parent as usize] = true;
        }
    }
    for (i, span) in spans.iter().enumerate() {
        if has_crit_child[i] && sums[i] != span.duration_ns() {
            return false;
        }
    }
    true
}

/// Structural well-formedness + conservation oracle shared by `figure12`
/// and the property suite.  Checks, for a finished non-dropped trace:
///
/// 1. span 0 is the `Request` root and every other span's parent precedes
///    it (indices form a forest rooted at 0);
/// 2. every span's interval is contained in its parent's;
/// 3. the critical children of any span are non-overlapping in recording
///    order and **tile** the parent exactly (no gaps: durations sum to
///    the parent's duration);
/// 4. unless the trace is truncated, the critical path sums bit-for-bit
///    to the recorded latency.
///
/// Returns a description of the first violation.
pub fn verify(trace: &Trace) -> Result<(), String> {
    let spans = &trace.spans;
    let Some(root) = spans.first() else {
        return Err("trace has no spans".into());
    };
    if root.kind != SpanKind::Request || root.parent != NO_PARENT {
        return Err("span 0 is not the Request root".into());
    }
    let mut crit_sum: Vec<u64> = vec![0; spans.len()];
    let mut crit_any: Vec<bool> = vec![false; spans.len()];
    let mut crit_cursor: Vec<u64> = spans.iter().map(|s| s.start_ns).collect();
    for (i, span) in spans.iter().enumerate() {
        if span.end_ns < span.start_ns {
            return Err(format!("span {i} ({}) ends before it starts", span.kind.name()));
        }
        if i == 0 {
            continue;
        }
        let p = span.parent as usize;
        if span.parent == NO_PARENT || p >= i {
            return Err(format!("span {i} has invalid parent {}", span.parent));
        }
        let parent = &spans[p];
        if span.start_ns < parent.start_ns || span.end_ns > parent.end_ns {
            return Err(format!(
                "span {i} ({}) [{}, {}] escapes parent {} ({}) [{}, {}]",
                span.kind.name(),
                span.start_ns,
                span.end_ns,
                p,
                parent.kind.name(),
                parent.start_ns,
                parent.end_ns
            ));
        }
        if span.crit {
            if span.start_ns < crit_cursor[p] {
                return Err(format!(
                    "critical span {i} ({}) overlaps a sibling on the critical path \
                     (starts {} before cursor {})",
                    span.kind.name(),
                    span.start_ns,
                    crit_cursor[p]
                ));
            }
            crit_cursor[p] = span.end_ns;
            crit_sum[p] += span.duration_ns();
            crit_any[p] = true;
        }
    }
    for (i, span) in spans.iter().enumerate() {
        if crit_any[i] && crit_sum[i] != span.duration_ns() {
            return Err(format!(
                "span {i} ({}) duration {} ns is not tiled by its critical children \
                 (sum {} ns)",
                span.kind.name(),
                span.duration_ns(),
                crit_sum[i]
            ));
        }
    }
    if !trace.dropped && !trace.truncated {
        let root_ms =
            std::time::Duration::from_nanos(root.duration_ns()).as_secs_f64() * 1e3;
        if root_ms.to_bits() != trace.latency_ms.to_bits() {
            return Err(format!(
                "critical path {root_ms} ms != recorded latency {} ms (bitwise)",
                trace.latency_ms
            ));
        }
        if !trace.conserved {
            return Err("tracer flagged the trace as non-conserved".into());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::run_virtual;

    fn params(sample_every: u64) -> TraceParams {
        TraceParams { sample_every, max_traces: 64, window_ms: 1_000.0 }
    }

    #[test]
    fn disabled_tracer_is_inert() {
        let t = Tracer::new(&params(0), 7);
        assert!(!t.enabled());
        assert!(t.begin_request(Sym::intern("f"), 0.0).is_none());
        t.finish_ok(None, 1.0);
        t.finish_dropped(None);
        assert_eq!(t.started(), 0);
        assert_eq!(t.approx_bytes(), 0);
        assert_eq!(t.snapshot().len(), 0);
        assert!(t.latency_breakdown_csv().ends_with("share_of_e2e\n"));
    }

    #[test]
    fn trace_records_and_conserves_a_synthetic_request() {
        run_virtual(async {
            let t = Tracer::new(&params(1), 7);
            let f = Sym::intern("syn");
            let t0 = exec::now();
            let ctx = t.begin_request(f, 0.0);
            assert!(ctx.is_some());
            let frame = t.open_frame(ctx, SpanKind::Invoke, f, true);
            let e0 = exec::now();
            exec::sleep_ms(10.0).await;
            t.add_parts(
                frame,
                e0,
                exec::now(),
                f,
                &[(SpanKind::Gateway, 4.0), (SpanKind::Network, 6.0)],
            );
            let seg = t.start_seg(frame, SpanKind::SelfTime, f);
            exec::sleep_ms(5.0).await;
            t.end_seg(seg);
            t.close_frame(frame);
            let latency_ms = exec::now().duration_since(t0).as_secs_f64() * 1e3;
            t.finish_ok(ctx, latency_ms);
            assert_eq!(t.conservation_violations(), 0);
            let traces = t.snapshot();
            assert_eq!(traces.len(), 1);
            let trace = &traces[0];
            assert!(trace.conserved);
            verify(trace).unwrap();
            // root + invoke + gateway + network + self
            assert_eq!(trace.spans.len(), 5);
            let kinds: Vec<&str> = trace.spans.iter().map(|s| s.kind.name()).collect();
            assert_eq!(kinds, vec!["request", "invoke", "gateway", "network", "self"]);
            // component partition is exact
            assert_eq!(trace.spans[2].duration_ns(), 4_000_000);
            assert_eq!(trace.spans[3].duration_ns(), 6_000_000);
            let csv = t.latency_breakdown_csv();
            assert!(csv.contains("syn,gateway"), "{csv}");
            assert!(csv.contains("syn,network"), "{csv}");
            let chrome = t.chrome_trace_json();
            assert!(chrome.contains("\"name\":\"gateway\""), "{chrome}");
            assert!(chrome.ends_with("]}"));
        });
    }

    #[test]
    fn zero_length_segments_are_elided() {
        run_virtual(async {
            let t = Tracer::new(&params(1), 7);
            let f = Sym::intern("z");
            let ctx = t.begin_request(f, 0.0);
            let frame = t.open_frame(ctx, SpanKind::Invoke, f, true);
            let seg = t.start_seg(frame, SpanKind::ColdWait, f);
            t.end_seg(seg); // no time passed
            t.close_frame(frame);
            t.finish_ok(ctx, 0.0);
            let traces = t.snapshot();
            assert_eq!(traces[0].spans.len(), 2, "{:?}", traces[0].spans);
        });
    }

    #[test]
    fn dropped_requests_are_always_retained_and_sampling_is_seeded() {
        async fn drive(t: &Tracer) {
            let f = Sym::intern("d");
            for i in 0..20 {
                let ctx = t.begin_request(f, i as f64 * 10.0);
                exec::sleep_ms(1.0).await;
                if i % 2 == 0 {
                    t.finish_dropped(ctx);
                } else {
                    t.finish_ok(ctx, 1.0);
                }
            }
        }
        run_virtual(async {
            // sample_every large: the 1-in-N draw almost never fires, yet
            // every dropped request and each window's first/slowest stay
            let t = Tracer::new(&params(1_000_000), 7);
            drive(&t).await;
            let traces = t.snapshot();
            let dropped = traces.iter().filter(|t| t.dropped).count();
            assert_eq!(dropped, 10);
            // same seed, same retention decisions
            let t2 = Tracer::new(&params(1_000_000), 7);
            drive(&t2).await;
            let a: Vec<u64> = t.snapshot().iter().map(|x| x.seq).collect();
            let b: Vec<u64> = t2.snapshot().iter().map(|x| x.seq).collect();
            assert_eq!(a, b);
        });
    }

    #[test]
    fn retained_ring_is_bounded() {
        run_virtual(async {
            let mut p = params(1);
            p.max_traces = 8;
            let t = Tracer::new(&p, 7);
            let f = Sym::intern("ring");
            for i in 0..50 {
                let ctx = t.begin_request(f, i as f64);
                exec::sleep_ms(1.0).await;
                t.finish_ok(ctx, 1.0);
            }
            assert_eq!(t.snapshot().len(), 8);
            assert_eq!(t.retained_total(), 50);
            assert!(t.approx_bytes() > 0);
        });
    }

    #[test]
    fn verify_rejects_malformed_trees() {
        let f = Sym::intern("bad");
        let mk = |spans: Vec<Span>| Trace {
            seq: 0,
            t_ms: 0.0,
            function: f,
            latency_ms: 1.0,
            dropped: false,
            truncated: false,
            conserved: true,
            reason: RetainReason::Sampled,
            spans,
        };
        let root = Span {
            kind: SpanKind::Request,
            function: f,
            parent: NO_PARENT,
            crit: false,
            start_ns: 0,
            end_ns: 1_000_000,
        };
        // child escapes the parent interval
        let escape = mk(vec![
            root,
            Span {
                kind: SpanKind::Invoke,
                function: f,
                parent: 0,
                crit: true,
                start_ns: 0,
                end_ns: 2_000_000,
            },
        ]);
        assert!(verify(&escape).unwrap_err().contains("escapes"));
        // critical children leave a gap
        let gap = mk(vec![
            root,
            Span {
                kind: SpanKind::Invoke,
                function: f,
                parent: 0,
                crit: true,
                start_ns: 0,
                end_ns: 500_000,
            },
        ]);
        assert!(verify(&gap).unwrap_err().contains("not tiled"));
        // a correct tiling passes
        let good = mk(vec![
            root,
            Span {
                kind: SpanKind::Invoke,
                function: f,
                parent: 0,
                crit: true,
                start_ns: 0,
                end_ns: 1_000_000,
            },
        ]);
        verify(&good).unwrap();
    }
}
