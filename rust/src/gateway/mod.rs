//! API gateway: function-name → replica-set routing with atomic
//! multi-route hot swap (the Merger's traffic-cutover step depends on it).
//!
//! On tinyFaaS the combined instance "overwrites the old function entries
//! in the API gateway"; on Kubernetes the equivalent is a Service backend
//! update (paper §4).  Both reduce to the same primitive: swap a set of
//! routes so no request ever observes a half-updated table.
//!
//! Since ISSUE 6 a route resolves to a [`ReplicaSet`], not a single
//! instance: the set load-balances across its healthy replicas with
//! power-of-two-choices on in-flight count.  All functions of a fused
//! group map to the **same** `Rc<ReplicaSet>`, so set identity
//! (`Rc::ptr_eq`) is the "fused together" relation the pipelines check.
//! The instance-level entry points ([`Gateway::set_route`],
//! [`Gateway::swap_routes`], [`Gateway::resolve`], …) are preserved: they
//! wrap their argument in a singleton set / pick a replica, so the seed's
//! one-instance-per-function call sites work unchanged and behave
//! identically at replica count 1.
//!
//! Routes are keyed by interned [`Sym`]s (ISSUE 5): `resolve_sym` is a
//! hash probe + `Rc` bump — zero heap allocations per call — and the
//! string-typed entry points intern once (allocation-free for any name
//! seen before) so existing callers keep working unchanged.
//!
//! Under the sharded simulation core (ISSUE 7) the routing table is
//! control-plane state: `Rc<Instance>` / `Rc<ReplicaSet>` handles resolved
//! here must never cross a shard boundary.  The dispatcher instead derives
//! the target's *lane index* ([`crate::cluster::Cluster::shard_of`]) and
//! pins the call's task there with `exec::spawn_on` — only `Send` wake
//! messages travel between lanes (see `docs/ARCHITECTURE.md`).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use crate::containerd::Instance;
use crate::error::{Error, Result};
use crate::replica::ReplicaSet;
use crate::util::intern::Sym;

/// Routing table handle (cheaply clonable, single-threaded interior
/// mutability).
#[derive(Clone, Default)]
pub struct Gateway {
    inner: Rc<GatewayInner>,
}

#[derive(Default)]
struct GatewayInner {
    routes: RefCell<HashMap<Sym, Rc<ReplicaSet>>>,
    /// bumped on every swap; lets tests assert atomicity
    version: Cell<u64>,
}

impl Gateway {
    /// An empty routing table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Install or replace a single route with a one-replica set (initial
    /// deployment; the seed's one-instance-per-function shape).
    pub fn set_route(&self, function: impl AsRef<str>, instance: Rc<Instance>) {
        self.set_route_set(function, ReplicaSet::singleton(instance));
    }

    /// Install or replace a single route with an explicit replica set.
    pub fn set_route_set(&self, function: impl AsRef<str>, set: Rc<ReplicaSet>) {
        self.inner.routes.borrow_mut().insert(Sym::intern(function.as_ref()), set);
        self.inner.version.set(self.inner.version.get() + 1);
    }

    /// Atomically repoint every function in `functions` to `instance` —
    /// the fused-instance cutover at replica count 1.  All functions share
    /// one singleton set (they are one fused group).  Either all routes
    /// change or none.
    pub fn swap_routes(&self, functions: &[String], instance: Rc<Instance>) -> Result<()> {
        self.swap_routes_set(functions, ReplicaSet::singleton(instance))
    }

    /// Atomically repoint every function in `functions` to the same
    /// replica `set` — the fused-set cutover.  Either all routes change or
    /// none.
    pub fn swap_routes_set(&self, functions: &[String], set: Rc<ReplicaSet>) -> Result<()> {
        let mut routes = self.inner.routes.borrow_mut();
        for f in functions {
            match Sym::lookup(f) {
                Some(sym) if routes.contains_key(&sym) => {}
                _ => return Err(Error::NoRoute(f.clone())),
            }
        }
        for f in functions {
            routes.insert(Sym::intern(f), Rc::clone(&set));
        }
        self.inner.version.set(self.inner.version.get() + 1);
        Ok(())
    }

    /// Atomically install a set of `(function, instance)` routes — the
    /// split pipeline's cutover at replica count 1, where every function
    /// returns to its own (singleton-set) instance.  Either all routes
    /// change or none.
    pub fn swap_routes_multi(&self, routes: &[(String, Rc<Instance>)]) -> Result<()> {
        let sets: Vec<(String, Rc<ReplicaSet>)> = routes
            .iter()
            .map(|(f, inst)| (f.clone(), ReplicaSet::singleton(Rc::clone(inst))))
            .collect();
        self.swap_routes_multi_sets(&sets)
    }

    /// Atomically install a set of `(function, replica set)` routes — the
    /// general split cutover.  Either all routes change or none.
    pub fn swap_routes_multi_sets(&self, routes: &[(String, Rc<ReplicaSet>)]) -> Result<()> {
        let mut table = self.inner.routes.borrow_mut();
        for (f, _) in routes {
            match Sym::lookup(f) {
                Some(sym) if table.contains_key(&sym) => {}
                _ => return Err(Error::NoRoute(f.clone())),
            }
        }
        for (f, set) in routes {
            table.insert(Sym::intern(f), Rc::clone(set));
        }
        self.inner.version.set(self.inner.version.get() + 1);
        Ok(())
    }

    /// Resolve a function name to a serving replica (load-balanced).
    /// Unknown names are rejected **without** growing the interner (this
    /// is the path client input reaches through the HTTP front end); the
    /// hot request path carries a [`Sym`] and uses [`Self::resolve_sym`].
    pub fn resolve(&self, function: &str) -> Result<Rc<Instance>> {
        match Sym::lookup(function) {
            Some(sym) => self.resolve_sym(sym),
            None => Err(Error::NoRoute(function.to_string())),
        }
    }

    /// Resolve an interned function to a serving replica: hash probe +
    /// power-of-two-choices pick.  A singleton set adds only a refcount
    /// bump over the pre-replica path (no RNG draw).  Errors when the
    /// route is unknown **or** the set currently has no routable replica
    /// (scaled to zero — the handler's scale-from-zero path resolves the
    /// set instead and boots a replica).
    pub fn resolve_sym(&self, function: Sym) -> Result<Rc<Instance>> {
        self.resolve_set_sym(function)?
            .pick()
            .ok_or_else(|| Error::NoRoute(function.as_str().to_string()))
    }

    /// Resolve a function name to its replica set.
    pub fn resolve_set(&self, function: &str) -> Result<Rc<ReplicaSet>> {
        match Sym::lookup(function) {
            Some(sym) => self.resolve_set_sym(sym),
            None => Err(Error::NoRoute(function.to_string())),
        }
    }

    /// Resolve an interned function to its replica set (the handler's hot
    /// path; zero heap allocations).
    pub fn resolve_set_sym(&self, function: Sym) -> Result<Rc<ReplicaSet>> {
        self.inner
            .routes
            .borrow()
            .get(&function)
            .cloned()
            .ok_or_else(|| Error::NoRoute(function.as_str().to_string()))
    }

    /// Snapshot of the full table as `(function, primary replica)` pairs
    /// (merger introspection, reports), sorted by name.  Routes whose set
    /// is currently scaled to zero are omitted (they have no instance to
    /// report).
    pub fn snapshot(&self) -> Vec<(String, Rc<Instance>)> {
        let mut v: Vec<(String, Rc<Instance>)> = self
            .inner
            .routes
            .borrow()
            .iter()
            .filter_map(|(k, set)| set.primary().map(|p| (k.as_str().to_string(), p)))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Interned snapshot (controller tick: no per-route `String`s), sorted
    /// by function name (one `as_str` per route, not per comparison).
    /// Scaled-to-zero routes are omitted, like [`Self::snapshot`].
    pub fn snapshot_syms(&self) -> Vec<(Sym, Rc<Instance>)> {
        let mut v: Vec<(Sym, Rc<Instance>)> = self
            .inner
            .routes
            .borrow()
            .iter()
            .filter_map(|(k, set)| set.primary().map(|p| (*k, p)))
            .collect();
        v.sort_by_cached_key(|(sym, _)| sym.as_str());
        v
    }

    /// Set-level snapshot, sorted by function name — the autoscaler's and
    /// controller tick's view.  Includes scaled-to-zero routes (their sets
    /// are what a scale-from-zero revives).
    pub fn snapshot_sets(&self) -> Vec<(String, Rc<ReplicaSet>)> {
        let mut v: Vec<(String, Rc<ReplicaSet>)> = self
            .inner
            .routes
            .borrow()
            .iter()
            .map(|(k, set)| (k.as_str().to_string(), Rc::clone(set)))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Monotone swap counter; lets tests assert cutover atomicity (an
    /// aborted swap leaves it unchanged).
    pub fn version(&self) -> u64 {
        self.inner.version.get()
    }

    /// Record an in-set topology change (a migration's one-replica
    /// [`ReplicaSet::replace`]) in the swap counter, keeping "the routed
    /// topology changed" observable even when no table entry moved.
    pub fn bump_version(&self) {
        self.inner.version.set(self.inner.version.get() + 1);
    }

    /// Number of routes in the table.
    pub fn len(&self) -> usize {
        self.inner.routes.borrow().len()
    }

    /// Whether the table has no routes at all.
    pub fn is_empty(&self) -> bool {
        self.inner.routes.borrow().is_empty()
    }

    /// Number of distinct instances currently routed to, across **all**
    /// replicas of all sets (at replica count 1 this is the seed's count
    /// of distinct routed instances, so "each merge removes exactly one
    /// instance" keeps holding).
    pub fn distinct_instances(&self) -> usize {
        let routes = self.inner.routes.borrow();
        let mut ids: Vec<u64> = routes
            .values()
            .flat_map(|set| set.replicas().into_iter().map(|i| i.id().0))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::containerd::{ContainerRuntime, FsManifest};

    fn setup() -> (ContainerRuntime, Gateway, Rc<Instance>, Rc<Instance>) {
        let rt = ContainerRuntime::new(Rc::new(PlatformConfig::tiny()));
        let img_a = rt.register_image(FsManifest::function_code("a", 1), vec![("a".into(), 9.0)]);
        let img_b = rt.register_image(FsManifest::function_code("b", 1), vec![("b".into(), 9.0)]);
        let gw = Gateway::new();
        let (ia, ib) = crate::exec::run_virtual({
            let rt = rt.clone();
            async move { (rt.launch(img_a).unwrap(), rt.launch(img_b).unwrap()) }
        });
        gw.set_route("a", Rc::clone(&ia));
        gw.set_route("b", Rc::clone(&ib));
        (rt, gw, ia, ib)
    }

    #[test]
    fn resolve_and_miss() {
        let (_rt, gw, ia, _ib) = setup();
        assert_eq!(gw.resolve("a").unwrap().id(), ia.id());
        assert_eq!(gw.resolve_sym(Sym::intern("a")).unwrap().id(), ia.id());
        assert!(matches!(gw.resolve("zz"), Err(Error::NoRoute(_))));
        assert!(matches!(gw.resolve_sym(Sym::intern("zz")), Err(Error::NoRoute(_))));
    }

    #[test]
    fn swap_is_all_or_nothing() {
        let (rt, gw, _ia, ib) = setup();
        let fused_img = rt.register_image(
            FsManifest::function_code("ab", 1),
            vec![("a".into(), 9.0), ("b".into(), 9.0)],
        );
        let fused = crate::exec::run_virtual({
            let rt = rt.clone();
            async move { rt.launch(fused_img).unwrap() }
        });
        let v0 = gw.version();
        // includes an unknown function -> must change nothing
        let err = gw.swap_routes(&["a".into(), "ghost".into()], Rc::clone(&fused));
        assert!(err.is_err());
        assert_eq!(gw.version(), v0);
        assert_ne!(gw.resolve("a").unwrap().id(), fused.id());

        gw.swap_routes(&["a".into(), "b".into()], Rc::clone(&fused)).unwrap();
        assert_eq!(gw.version(), v0 + 1);
        assert_eq!(gw.resolve("a").unwrap().id(), fused.id());
        assert_eq!(gw.resolve("b").unwrap().id(), fused.id());
        assert_eq!(gw.distinct_instances(), 1);
        // both names share ONE set: the fused-together relation
        assert!(Rc::ptr_eq(
            &gw.resolve_set("a").unwrap(),
            &gw.resolve_set("b").unwrap()
        ));
        drop(ib);
    }

    #[test]
    fn snapshot_sorted() {
        let (_rt, gw, _a, _b) = setup();
        let snap = gw.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "a");
        assert_eq!(snap[1].0, "b");
        let syms = gw.snapshot_syms();
        assert_eq!(syms.len(), 2);
        assert_eq!(syms[0].0.as_str(), "a");
        assert_eq!(syms[1].0.as_str(), "b");
        let sets = gw.snapshot_sets();
        assert_eq!(sets.len(), 2);
        assert_eq!(sets[0].0, "a");
    }

    #[test]
    fn swap_multi_is_all_or_nothing() {
        let (_rt, gw, ia, ib) = setup();
        // fuse both routes onto one instance first
        gw.swap_routes(&["a".into(), "b".into()], Rc::clone(&ia)).unwrap();
        assert_eq!(gw.distinct_instances(), 1);
        let v0 = gw.version();

        // unknown function -> nothing changes
        let err = gw.swap_routes_multi(&[
            ("a".into(), Rc::clone(&ia)),
            ("ghost".into(), Rc::clone(&ib)),
        ]);
        assert!(err.is_err());
        assert_eq!(gw.version(), v0);
        assert_eq!(gw.resolve("b").unwrap().id(), ia.id());

        // split cutover: each function back to its own instance
        gw.swap_routes_multi(&[("a".into(), Rc::clone(&ia)), ("b".into(), Rc::clone(&ib))])
            .unwrap();
        assert_eq!(gw.version(), v0 + 1);
        assert_eq!(gw.resolve("a").unwrap().id(), ia.id());
        assert_eq!(gw.resolve("b").unwrap().id(), ib.id());
        assert_eq!(gw.distinct_instances(), 2);
    }

    #[test]
    fn multi_replica_route_resolves_and_counts_all_replicas() {
        let (rt, gw, ia, _ib) = setup();
        let img = ia.image();
        let extra = crate::exec::run_virtual({
            let rt = rt.clone();
            async move { rt.launch(img).unwrap() }
        });
        let set = gw.resolve_set("a").unwrap();
        set.add(Rc::clone(&extra));
        // resolve returns one of the two replicas, never b's
        for _ in 0..20 {
            let picked = gw.resolve("a").unwrap().id();
            assert!(picked == ia.id() || picked == extra.id());
        }
        // 2 replicas of a + 1 of b
        assert_eq!(gw.distinct_instances(), 3);
        // scaled to zero: resolve errors, resolve_set still works
        set.remove(ia.id());
        set.remove(extra.id());
        assert!(matches!(gw.resolve("a"), Err(Error::NoRoute(_))));
        assert!(gw.resolve_set("a").is_ok());
        assert_eq!(gw.snapshot().len(), 1, "scaled-to-zero route omitted from snapshot");
        assert_eq!(gw.snapshot_sets().len(), 2, "set snapshot keeps it");
    }
}
