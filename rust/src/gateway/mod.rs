//! API gateway: function-name → instance routing with atomic multi-route
//! hot swap (the Merger's traffic-cutover step depends on it).
//!
//! On tinyFaaS the combined instance "overwrites the old function entries
//! in the API gateway"; on Kubernetes the equivalent is a Service backend
//! update (paper §4).  Both reduce to the same primitive: swap a set of
//! routes so no request ever observes a half-updated table.
//!
//! Routes are keyed by interned [`Sym`]s (ISSUE 5): `resolve_sym` is a
//! hash probe + `Rc` bump — zero heap allocations per call — and the
//! string-typed entry points intern once (allocation-free for any name
//! seen before) so existing callers keep working unchanged.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use crate::containerd::Instance;
use crate::error::{Error, Result};
use crate::util::intern::Sym;

/// Routing table handle (cheaply clonable, single-threaded interior
/// mutability).
#[derive(Clone, Default)]
pub struct Gateway {
    inner: Rc<GatewayInner>,
}

#[derive(Default)]
struct GatewayInner {
    routes: RefCell<HashMap<Sym, Rc<Instance>>>,
    /// bumped on every swap; lets tests assert atomicity
    version: Cell<u64>,
}

impl Gateway {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install or replace a single route (initial deployment).
    pub fn set_route(&self, function: impl AsRef<str>, instance: Rc<Instance>) {
        self.inner
            .routes
            .borrow_mut()
            .insert(Sym::intern(function.as_ref()), instance);
        self.inner.version.set(self.inner.version.get() + 1);
    }

    /// Atomically repoint every function in `functions` to `instance` —
    /// the fused-instance cutover.  Either all routes change or none.
    pub fn swap_routes(&self, functions: &[String], instance: Rc<Instance>) -> Result<()> {
        let mut routes = self.inner.routes.borrow_mut();
        for f in functions {
            match Sym::lookup(f) {
                Some(sym) if routes.contains_key(&sym) => {}
                _ => return Err(Error::NoRoute(f.clone())),
            }
        }
        for f in functions {
            routes.insert(Sym::intern(f), Rc::clone(&instance));
        }
        self.inner.version.set(self.inner.version.get() + 1);
        Ok(())
    }

    /// Atomically install a set of `(function, instance)` routes — the
    /// split pipeline's cutover, where every function returns to its own
    /// instance.  Either all routes change or none.
    pub fn swap_routes_multi(&self, routes: &[(String, Rc<Instance>)]) -> Result<()> {
        let mut table = self.inner.routes.borrow_mut();
        for (f, _) in routes {
            match Sym::lookup(f) {
                Some(sym) if table.contains_key(&sym) => {}
                _ => return Err(Error::NoRoute(f.clone())),
            }
        }
        for (f, inst) in routes {
            table.insert(Sym::intern(f), Rc::clone(inst));
        }
        self.inner.version.set(self.inner.version.get() + 1);
        Ok(())
    }

    /// Resolve a function name to its current instance.  Unknown names are
    /// rejected **without** growing the interner (this is the path client
    /// input reaches through the HTTP front end); the hot request path
    /// carries a [`Sym`] and uses [`Self::resolve_sym`].
    pub fn resolve(&self, function: &str) -> Result<Rc<Instance>> {
        match Sym::lookup(function) {
            Some(sym) => self.resolve_sym(sym),
            None => Err(Error::NoRoute(function.to_string())),
        }
    }

    /// Resolve an interned function to its current instance.  Hash probe +
    /// refcount bump: zero heap allocations on the hit path.
    pub fn resolve_sym(&self, function: Sym) -> Result<Rc<Instance>> {
        self.inner
            .routes
            .borrow()
            .get(&function)
            .cloned()
            .ok_or_else(|| Error::NoRoute(function.as_str().to_string()))
    }

    /// Snapshot of the full table (merger introspection, reports).
    pub fn snapshot(&self) -> Vec<(String, Rc<Instance>)> {
        let mut v: Vec<(String, Rc<Instance>)> = self
            .inner
            .routes
            .borrow()
            .iter()
            .map(|(k, inst)| (k.as_str().to_string(), Rc::clone(inst)))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Interned snapshot (controller tick: no per-route `String`s), sorted
    /// by function name (one `as_str` per route, not per comparison).
    pub fn snapshot_syms(&self) -> Vec<(Sym, Rc<Instance>)> {
        let mut v: Vec<(Sym, Rc<Instance>)> = self
            .inner
            .routes
            .borrow()
            .iter()
            .map(|(k, inst)| (*k, Rc::clone(inst)))
            .collect();
        v.sort_by_cached_key(|(sym, _)| sym.as_str());
        v
    }

    pub fn version(&self) -> u64 {
        self.inner.version.get()
    }

    pub fn len(&self) -> usize {
        self.inner.routes.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.routes.borrow().is_empty()
    }

    /// Number of distinct instances currently routed to.
    pub fn distinct_instances(&self) -> usize {
        let routes = self.inner.routes.borrow();
        let mut ids: Vec<u64> = routes.values().map(|i| i.id().0).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::containerd::{ContainerRuntime, FsManifest};

    fn setup() -> (ContainerRuntime, Gateway, Rc<Instance>, Rc<Instance>) {
        let rt = ContainerRuntime::new(Rc::new(PlatformConfig::tiny()));
        let img_a = rt.register_image(FsManifest::function_code("a", 1), vec![("a".into(), 9.0)]);
        let img_b = rt.register_image(FsManifest::function_code("b", 1), vec![("b".into(), 9.0)]);
        let gw = Gateway::new();
        let (ia, ib) = crate::exec::run_virtual({
            let rt = rt.clone();
            async move { (rt.launch(img_a).unwrap(), rt.launch(img_b).unwrap()) }
        });
        gw.set_route("a", Rc::clone(&ia));
        gw.set_route("b", Rc::clone(&ib));
        (rt, gw, ia, ib)
    }

    #[test]
    fn resolve_and_miss() {
        let (_rt, gw, ia, _ib) = setup();
        assert_eq!(gw.resolve("a").unwrap().id(), ia.id());
        assert_eq!(gw.resolve_sym(Sym::intern("a")).unwrap().id(), ia.id());
        assert!(matches!(gw.resolve("zz"), Err(Error::NoRoute(_))));
        assert!(matches!(gw.resolve_sym(Sym::intern("zz")), Err(Error::NoRoute(_))));
    }

    #[test]
    fn swap_is_all_or_nothing() {
        let (rt, gw, _ia, ib) = setup();
        let fused_img = rt.register_image(
            FsManifest::function_code("ab", 1),
            vec![("a".into(), 9.0), ("b".into(), 9.0)],
        );
        let fused = crate::exec::run_virtual({
            let rt = rt.clone();
            async move { rt.launch(fused_img).unwrap() }
        });
        let v0 = gw.version();
        // includes an unknown function -> must change nothing
        let err = gw.swap_routes(&["a".into(), "ghost".into()], Rc::clone(&fused));
        assert!(err.is_err());
        assert_eq!(gw.version(), v0);
        assert_ne!(gw.resolve("a").unwrap().id(), fused.id());

        gw.swap_routes(&["a".into(), "b".into()], Rc::clone(&fused)).unwrap();
        assert_eq!(gw.version(), v0 + 1);
        assert_eq!(gw.resolve("a").unwrap().id(), fused.id());
        assert_eq!(gw.resolve("b").unwrap().id(), fused.id());
        assert_eq!(gw.distinct_instances(), 1);
        drop(ib);
    }

    #[test]
    fn snapshot_sorted() {
        let (_rt, gw, _a, _b) = setup();
        let snap = gw.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "a");
        assert_eq!(snap[1].0, "b");
        let syms = gw.snapshot_syms();
        assert_eq!(syms.len(), 2);
        assert_eq!(syms[0].0.as_str(), "a");
        assert_eq!(syms[1].0.as_str(), "b");
    }

    #[test]
    fn swap_multi_is_all_or_nothing() {
        let (_rt, gw, ia, ib) = setup();
        // fuse both routes onto one instance first
        gw.swap_routes(&["a".into(), "b".into()], Rc::clone(&ia)).unwrap();
        assert_eq!(gw.distinct_instances(), 1);
        let v0 = gw.version();

        // unknown function -> nothing changes
        let err = gw.swap_routes_multi(&[
            ("a".into(), Rc::clone(&ia)),
            ("ghost".into(), Rc::clone(&ib)),
        ]);
        assert!(err.is_err());
        assert_eq!(gw.version(), v0);
        assert_eq!(gw.resolve("b").unwrap().id(), ia.id());

        // split cutover: each function back to its own instance
        gw.swap_routes_multi(&[("a".into(), Rc::clone(&ia)), ("b".into(), Rc::clone(&ib))])
            .unwrap();
        assert_eq!(gw.version(), v0 + 1);
        assert_eq!(gw.resolve("a").unwrap().id(), ia.id());
        assert_eq!(gw.resolve("b").unwrap().id(), ib.id());
        assert_eq!(gw.distinct_instances(), 2);
    }
}
