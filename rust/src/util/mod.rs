//! Substrate utilities built in-repo (the offline crate set has no rand /
//! serde / proptest): deterministic RNG, statistics, JSON, and a mini
//! property-testing harness.

pub mod args;
pub mod bench;
pub mod intern;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
