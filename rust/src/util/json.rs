//! Minimal JSON substrate (no `serde` offline): a value model, a
//! recursive-descent parser, and a writer.  Used for the artifact manifest,
//! goldens, experiment reports, and config files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.  Objects use `BTreeMap` for deterministic output ordering.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.pos)));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Json(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Json(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Json(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(Error::Json(format!("expected object, got {other:?}"))),
        }
    }

    /// Object field access with a path-aware error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing field `{key}`")))
    }

    /// Array of numbers -> `Vec<f32>` (payload/golden vectors).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    // -- writer ---------------------------------------------------------------

    #[allow(clippy::inherent_to_string)] // deliberate: compact JSON encoder
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Convenience constructors.
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(values.into_iter().map(Json::Num).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected `{}` at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(Error::Json(format!("invalid literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(Error::Json("truncated \\u escape".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => {
                            return Err(Error::Json(format!("bad escape {other:?}")));
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| Error::Json("invalid utf-8 in string".into()))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number `{text}`: {e}")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected , or ] at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            out.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected , or }} at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"batch":8,"bodies":[{"hlo":"x.hlo.txt","name":"x"}],"in":256}"#,
            r#"[0.5,-1,2e3,"s",true,null]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn roundtrip_random_floats() {
        let mut rng = crate::util::rng::Rng::new(99);
        let vals: Vec<f64> = (0..200).map(|_| rng.normal() * 1e3).collect();
        let text = Json::arr_f64(vals.clone()).to_string();
        let back = Json::parse(&text).unwrap();
        for (a, b) in vals.iter().zip(back.as_arr().unwrap()) {
            let b = b.as_f64().unwrap();
            assert!((a - b).abs() <= a.abs() * 1e-12);
        }
    }

    #[test]
    fn escapes() {
        let s = Json::Str("a\"b\\c\nd\u{1}".into()).to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, Json::Str("a\"b\\c\nd\u{1}".into()));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo ☃""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
        let esc = Json::parse(r#""☃""#).unwrap();
        assert_eq!(esc.as_str().unwrap(), "☃");
    }

    #[test]
    fn f32_vec() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }
}
