//! Statistics substrate: online summaries, quantiles, and a log-bucketed
//! latency histogram (no external crates available offline).

/// Online mean/variance/min/max accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact quantiles over a stored sample set (fine at experiment scale).
#[derive(Debug, Clone, Default)]
pub struct Quantiles {
    sorted: Vec<f64>,
}

impl Quantiles {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        samples.retain(|x| x.is_finite());
        // total_cmp: total order, no unwrap-on-NaN panic path, and faster
        // than partial_cmp (no Option in the comparator)
        samples.sort_unstable_by(f64::total_cmp);
        Quantiles { sorted: samples }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// The retained (finite, sorted) samples — lets callers that hold
    /// several per-lane `Quantiles` pool them into one distribution
    /// (`from_samples` re-sorts the concatenation).
    pub fn samples(&self) -> &[f64] {
        &self.sorted
    }

    /// Quantile by linear interpolation; `q` in `[0, 1]`.
    pub fn q(&self, q: f64) -> f64 {
        quantile_sorted(&self.sorted, q)
    }

    pub fn median(&self) -> f64 {
        self.q(0.5)
    }
    pub fn p95(&self) -> f64 {
        self.q(0.95)
    }
    pub fn p99(&self) -> f64 {
        self.q(0.99)
    }
    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            f64::NAN
        } else {
            self.sorted.iter().sum::<f64>() / self.sorted.len() as f64
        }
    }
}

/// Quantile by linear interpolation over an **already-sorted** slice —
/// the allocation-free primitive behind [`Quantiles::q`], shared with the
/// metrics recorder's windowed shards so both paths are bit-identical by
/// construction.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Log2-bucketed histogram for hot-path timing (constant memory, ~7%
/// relative resolution with 4 sub-buckets per octave).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// bucket counts; index = octave * SUB + sub-bucket
    counts: Vec<u64>,
    unit_ns: f64,
    total: u64,
    sum: f64,
}

const SUB: usize = 8;
const OCTAVES: usize = 40;

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram { counts: vec![0; SUB * OCTAVES], unit_ns: 1.0, total: 0, sum: 0.0 }
    }

    /// Bucket index = `floor(log2(v / unit) * SUB)`, computed from the IEEE
    /// exponent + mantissa bits instead of `f64::log2` (ISSUE 5 satellite:
    /// a transcendental per `record` on the hot path for what is an integer
    /// question).  The exponent field *is* `floor(log2 r)` for normal
    /// `r >= 1`, and the sub-bucket is how many octave boundaries
    /// `2^(j/SUB)` the mantissa clears — a ≤7-step table walk.
    fn index(&self, v: f64) -> usize {
        // boundaries 2^(j/8) for j = 0..8 within one octave
        const SUB_BOUNDS: [f64; SUB] = [
            1.0,
            1.0905077326652577, // 2^(1/8)
            1.189207115002721,  // 2^(2/8)
            1.2968395546510096, // 2^(3/8)
            1.4142135623730951, // 2^(4/8)
            1.5422108254079407, // 2^(5/8)
            1.681792830507429,  // 2^(6/8)
            1.8340080864093424, // 2^(7/8)
        ];
        if v < self.unit_ns {
            return 0;
        }
        let r = v / self.unit_ns;
        if r < 1.0 {
            // v ~ unit but the division rounded below 1 (negative exponent)
            return 0;
        }
        let bits = r.to_bits();
        let exp = ((bits >> 52) & 0x7ff) as usize - 1023; // floor(log2 r), r >= 1
        let mantissa = bits & ((1u64 << 52) - 1);
        // the mantissa re-biased into [1, 2): r / 2^exp
        let frac = f64::from_bits(mantissa | (1023u64 << 52));
        let mut sub = 0usize;
        while sub + 1 < SUB && frac >= SUB_BOUNDS[sub + 1] {
            sub += 1;
        }
        (exp * SUB + sub).min(self.counts.len() - 1)
    }

    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        let idx = self.index(v);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.total
    }
    pub fn mean(&self) -> f64 {
        if self.total == 0 { f64::NAN } else { self.sum / self.total as f64 }
    }

    /// Reset in place, keeping the bucket allocation (ring-shard reuse).
    pub fn clear(&mut self) {
        self.counts.fill(0);
        self.total = 0;
        self.sum = 0.0;
    }

    /// Accumulate another histogram's counts (same unit/bucketing) — the
    /// O(#buckets) merge the windowed telemetry shards use for approximate
    /// cross-bucket quantiles.
    pub fn merge_from(&mut self, other: &LogHistogram) {
        for (dst, src) in self.counts.iter_mut().zip(other.counts.iter()) {
            *dst += src;
        }
        self.total += other.total;
        self.sum += other.sum;
    }

    /// Heap footprint (memory-accounting support for the recorder's
    /// bounded-memory self-checks).
    pub fn approx_bytes(&self) -> usize {
        self.counts.capacity() * std::mem::size_of::<u64>()
    }

    /// Approximate quantile (upper edge of the containing bucket).
    pub fn q(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return self.unit_ns * 2f64.powf((i + 1) as f64 / SUB as f64);
            }
        }
        f64::NAN
    }
}

/// Convenience: format milliseconds human-readably.
pub fn fmt_ms(ms: f64) -> String {
    if ms.is_nan() {
        "n/a".into()
    } else if ms < 1.0 {
        format!("{:.3} ms", ms)
    } else if ms < 1000.0 {
        format!("{:.1} ms", ms)
    } else {
        format!("{:.2} s", ms / 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        assert!(Summary::new().mean().is_nan());
    }

    #[test]
    fn quantiles_exact() {
        let q = Quantiles::from_samples((1..=100).map(|i| i as f64).collect());
        assert!((q.median() - 50.5).abs() < 1e-9);
        assert!((q.q(0.0) - 1.0).abs() < 1e-9);
        assert!((q.q(1.0) - 100.0).abs() < 1e-9);
        assert!((q.p99() - 99.01).abs() < 0.02);
    }

    #[test]
    fn quantiles_single() {
        let q = Quantiles::from_samples(vec![7.0]);
        assert_eq!(q.median(), 7.0);
        assert_eq!(q.p99(), 7.0);
    }

    #[test]
    fn quantile_monotone() {
        let mut v = Vec::new();
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..1000 {
            v.push(rng.lognormal(10.0, 1.0));
        }
        let q = Quantiles::from_samples(v);
        let mut last = f64::NEG_INFINITY;
        for i in 0..=20 {
            let cur = q.q(i as f64 / 20.0);
            assert!(cur >= last);
            last = cur;
        }
    }

    #[test]
    fn log_histogram_quantile_accuracy() {
        let mut h = LogHistogram::new();
        let mut exact = Vec::new();
        let mut rng = crate::util::rng::Rng::new(21);
        for _ in 0..50_000 {
            let v = rng.lognormal(1e6, 0.8); // ~1ms in ns
            h.record(v);
            exact.push(v);
        }
        let q = Quantiles::from_samples(exact);
        for p in [0.5, 0.9, 0.99] {
            let approx = h.q(p);
            let truth = q.q(p);
            let rel = (approx - truth).abs() / truth;
            assert!(rel < 0.15, "p{p}: approx {approx} vs {truth}");
        }
    }

    #[test]
    fn log_histogram_ignores_garbage() {
        let mut h = LogHistogram::new();
        h.record(f64::NAN);
        h.record(-5.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn integer_bucketing_matches_log2_bucketing() {
        // ISSUE 5 satellite: the bit-twiddled `index` must agree with the
        // old `floor(log2(v/unit) * SUB)` formula it replaced.
        let h = LogHistogram::new();
        let old_index = |v: f64| -> usize {
            if v < 1.0 {
                return 0;
            }
            let l = v.log2();
            let idx = (l * SUB as f64) as usize;
            idx.min(SUB * OCTAVES - 1)
        };
        // hand-picked non-boundary values across the range + the clamp edge
        for v in [0.0, 0.5, 1.0, 1.3, 2.0, 3.7, 100.0, 1e6, 1e9, 1e300] {
            assert_eq!(h.index(v), old_index(v), "v = {v}");
        }
        // broad randomized agreement (lognormal spans many octaves)
        let mut rng = crate::util::rng::Rng::new(42);
        for _ in 0..20_000 {
            let v = rng.lognormal(1e6, 2.0);
            assert_eq!(h.index(v), old_index(v), "v = {v}");
        }
    }

    #[test]
    fn log_histogram_clear_and_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for v in [10.0, 100.0, 1_000.0] {
            a.record(v);
        }
        for v in [20.0, 200.0] {
            b.record(v);
        }
        let mut merged = LogHistogram::new();
        merged.merge_from(&a);
        merged.merge_from(&b);
        assert_eq!(merged.count(), 5);
        assert!((merged.mean() - (10.0 + 100.0 + 1_000.0 + 20.0 + 200.0) / 5.0).abs() < 1e-9);
        a.clear();
        assert_eq!(a.count(), 0);
        assert!(a.mean().is_nan());
        a.record(50.0);
        assert_eq!(a.count(), 1);
    }

    #[test]
    fn quantile_sorted_matches_quantiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let q = Quantiles::from_samples(v.clone());
        for p in [0.0, 0.25, 0.5, 0.95, 1.0] {
            assert_eq!(q.q(p), quantile_sorted(&v, p));
        }
        assert!(quantile_sorted(&[], 0.5).is_nan());
    }
}
