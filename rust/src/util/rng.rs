//! Deterministic PRNG + samplers (substrate: no `rand` crate offline).
//!
//! `SplitMix64` seeds `Xoshiro256StarStar` (public-domain reference
//! algorithms); samplers cover the distributions the latency fabric and
//! workload generator need.  Everything is reproducible from a `u64` seed.

/// SplitMix64 — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal variate from Box-Muller
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (for per-request / per-edge rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine for sim use.
        (self.f64() * n as f64) as u64 % n
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal parameterized by the *target* median and sigma of the
    /// underlying normal — heavy-tailed hop latencies.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (median.ln() + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        loop {
            let u = self.f64();
            if u > 0.0 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Fill a payload vector with standard normal f32s.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_is_independent() {
        let mut a = Rng::new(7);
        let mut c1 = a.fork(1);
        let mut c2 = a.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let lambda = 0.5;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(13);
        let n = 100_001;
        let mut v: Vec<f64> = (0..n).map(|_| r.lognormal(8.0, 0.3)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = v[n / 2];
        assert!((med - 8.0).abs() < 0.2, "median {med}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }
}
