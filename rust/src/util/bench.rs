//! Criterion-style micro-bench harness (no `criterion` offline): warmup,
//! timed iterations, mean/p50/p99 report.  Used by the `cargo bench`
//! targets (`harness = false`).

use std::time::Instant;

use crate::util::stats::Quantiles;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns)
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones and
/// print a criterion-like row.  Returns the stats for programmatic use.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let q = Quantiles::from_samples(samples);
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: q.mean(),
        p50_ns: q.median(),
        p99_ns: q.p99(),
    };
    println!("{}", result.row());
    result
}

/// Time a single long-running closure (experiment regenerations).
pub fn once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let value = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:<44} completed in {secs:.2} s (wall)");
    (value, secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 2, 50, || 1 + 1);
        assert_eq!(r.iters, 50);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
