//! String interning for the telemetry pipeline and the request hot path.
//!
//! Function names are the platform's universal key: every request hop, every
//! telemetry sample, and every fusion decision is keyed by one.  The seed
//! tree cloned a fresh `String` per hop and per sample; at figure-9 scale
//! (10⁶+ requests) those clones dominate the allocator.  [`Sym`] replaces
//! them with a `u32` handle into a process-wide table — `Copy`, `Eq` by
//! integer compare, and resolvable back to `&'static str` for display and
//! CSV export.  [`GroupKey`] does the same for fused-group identities,
//! replacing the ad-hoc `functions.join("+")` the controller tick used to
//! rebuild every interval.
//!
//! The table is append-only and global (a `Mutex` around two maps): interned
//! names are leaked once, so `as_str` hands out `&'static str` without
//! copying.  The set of function names and group identities in any run is
//! tiny and bounded by the app spec, so the leak is a few hundred bytes for
//! the lifetime of the process — the classic interner trade.
//!
//! Lock discipline: every public call acquires the mutex once and never
//! re-enters (helpers that need name strings read `names` directly instead
//! of calling `as_str`), so the API cannot self-deadlock.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Mutex, OnceLock};

/// Interned function name: `Copy`, integer equality/ordering (interning
/// order, *not* lexicographic — sort by [`Sym::as_str`] when name order
/// matters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Sym(u32);

/// Interned canonical fused-group identity: the `+`-joined, name-sorted
/// member list, interned once when the group first forms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GroupKey(u32);

struct GroupEntry {
    /// the `+`-joined canonical name, itself interned
    name: Sym,
}

#[derive(Default)]
struct Interner {
    by_name: HashMap<&'static str, u32>,
    names: Vec<&'static str>,
    group_by_members: HashMap<Box<[Sym]>, u32>,
    groups: Vec<GroupEntry>,
}

impl Interner {
    /// Intern `name` without allocating on the hit path.
    fn intern_str(&mut self, name: &str) -> Sym {
        if let Some(&id) = self.by_name.get(name) {
            return Sym(id);
        }
        self.intern_owned(name.to_string())
    }

    /// Intern an already-owned string (single allocation path).
    fn intern_owned(&mut self, name: String) -> Sym {
        if let Some(&id) = self.by_name.get(name.as_str()) {
            return Sym(id);
        }
        let leaked: &'static str = Box::leak(name.into_boxed_str());
        let id = self.names.len() as u32;
        self.names.push(leaked);
        self.by_name.insert(leaked, id);
        Sym(id)
    }
}

fn table() -> &'static Mutex<Interner> {
    static TABLE: OnceLock<Mutex<Interner>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(Interner::default()))
}

impl Sym {
    /// Intern `name` (allocation-free when already interned).
    pub fn intern(name: &str) -> Sym {
        table().lock().unwrap().intern_str(name)
    }

    /// Resolve an already-interned name **without inserting** — the
    /// untrusted-input path: the table is append-only and leaks each name
    /// for the process lifetime, so gateway lookups fed by arbitrary
    /// client strings must not grow it (every legitimately routable name
    /// was interned at deploy time).
    pub fn lookup(name: &str) -> Option<Sym> {
        table().lock().unwrap().by_name.get(name).copied().map(Sym)
    }

    /// The interned name (leaked once at interning time, so `'static`).
    pub fn as_str(self) -> &'static str {
        table().lock().unwrap().names[self.0 as usize]
    }
}

impl fmt::Display for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl From<&str> for Sym {
    fn from(s: &str) -> Sym {
        Sym::intern(s)
    }
}

impl GroupKey {
    /// Intern the group identified by `members`, which **must already be
    /// sorted by name** (the canonical group order every layer uses).
    /// Allocation-free once the group has been seen — the per-tick path.
    pub fn from_members(members: &[Sym]) -> GroupKey {
        let mut t = table().lock().unwrap();
        debug_assert!(
            members
                .windows(2)
                .all(|w| t.names[w[0].0 as usize] <= t.names[w[1].0 as usize]),
            "GroupKey members must be sorted by name"
        );
        if let Some(&id) = t.group_by_members.get(members) {
            return GroupKey(id);
        }
        let joined = members
            .iter()
            .map(|s| t.names[s.0 as usize])
            .collect::<Vec<&str>>()
            .join("+");
        let name = t.intern_owned(joined);
        let id = t.groups.len() as u32;
        t.group_by_members
            .insert(members.to_vec().into_boxed_slice(), id);
        t.groups.push(GroupEntry { name });
        GroupKey(id)
    }

    /// Intern a group from its canonical `+`-joined name (report/test
    /// convenience; members are derived by splitting on `+`).
    pub fn from_name(name: &str) -> GroupKey {
        let members: Vec<Sym> = name.split('+').map(Sym::intern).collect();
        GroupKey::from_members(&members)
    }

    /// The canonical `+`-joined name as an interned symbol.
    pub fn name(self) -> Sym {
        table().lock().unwrap().groups[self.0 as usize].name
    }

    /// The canonical `+`-joined name.
    pub fn as_str(self) -> &'static str {
        let t = table().lock().unwrap();
        let sym = t.groups[self.0 as usize].name;
        t.names[sym.0 as usize]
    }
}

impl fmt::Display for GroupKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_idempotent_and_stable() {
        let a = Sym::intern("intern-test-a");
        let b = Sym::intern("intern-test-b");
        assert_ne!(a, b);
        assert_eq!(a, Sym::intern("intern-test-a"));
        assert_eq!(a.as_str(), "intern-test-a");
        assert_eq!(b.to_string(), "intern-test-b");
        let c: Sym = "intern-test-a".into();
        assert_eq!(a, c);
    }

    #[test]
    fn lookup_never_inserts() {
        assert!(Sym::lookup("intern-test-never-interned").is_none());
        // ... even after the probe, the name is still absent
        assert!(Sym::lookup("intern-test-never-interned").is_none());
        let s = Sym::intern("intern-test-looked-up");
        assert_eq!(Sym::lookup("intern-test-looked-up"), Some(s));
    }

    #[test]
    fn group_key_canonical_name_and_cache() {
        let a = Sym::intern("ga");
        let b = Sym::intern("gb");
        let k = GroupKey::from_members(&[a, b]);
        assert_eq!(k.as_str(), "ga+gb");
        assert_eq!(k.name().as_str(), "ga+gb");
        // second interning hits the cache and returns the same key
        assert_eq!(k, GroupKey::from_members(&[a, b]));
        // name-based interning resolves to the identical key
        assert_eq!(k, GroupKey::from_name("ga+gb"));
        // a different membership is a different key
        let c = Sym::intern("gc");
        assert_ne!(k, GroupKey::from_members(&[a, c]));
    }

    #[test]
    fn singleton_group_round_trips() {
        let k = GroupKey::from_name("solo-fn");
        assert_eq!(k.as_str(), "solo-fn");
        assert_eq!(k, GroupKey::from_members(&[Sym::intern("solo-fn")]));
    }
}
