//! Tiny CLI argument parser (no `clap` offline): subcommand + `--flag
//! [value]` pairs + positionals.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding `argv[0]`).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("empty flag `--`".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".into());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got `{v}`"))),
        }
    }

    pub fn u32_or(&self, name: &str, default: u32) -> Result<u32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got `{v}`"))),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects a number, got `{v}`"))),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_flags_positionals() {
        let a = parse("figure5 --out results --requests 100 extra");
        assert_eq!(a.command.as_deref(), Some("figure5"));
        assert_eq!(a.flag("out"), Some("results"));
        assert_eq!(a.u64_or("requests", 0).unwrap(), 100);
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn boolean_and_equals_flags() {
        let a = parse("run --live --rate=2.5");
        assert!(a.has("live"));
        assert_eq!(a.f64_or("rate", 0.0).unwrap(), 2.5);
        assert!(!a.has("absent"));
        assert_eq!(a.u64_or("absent", 7).unwrap(), 7);
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("cmd --a --b v");
        assert_eq!(a.flag("a"), Some("true"));
        assert_eq!(a.flag("b"), Some("v"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("cmd --n abc");
        assert!(a.u64_or("n", 1).is_err());
        assert!(a.u32_or("n", 1).is_err());
        assert!(a.f64_or("n", 1.0).is_err());
    }

    #[test]
    fn u32_flag_parses_with_default() {
        let a = parse("cmd --hysteresis 4");
        assert_eq!(a.u32_or("hysteresis", 1).unwrap(), 4);
        assert_eq!(a.u32_or("absent", 2).unwrap(), 2);
    }

    #[test]
    fn valueless_flag_reads_as_true_for_policy_switches() {
        // `--cost-model` alone must surface as the value "true", which
        // `config::SplitPolicyKind::parse` accepts as the CostModel policy
        let a = parse("cmd --cost-model");
        assert_eq!(a.flag("cost-model"), Some("true"));
        let b = parse("cmd --cost-model threshold");
        assert_eq!(b.flag("cost-model"), Some("threshold"));
    }

    #[test]
    fn merge_planner_flags_parse() {
        // the merge-side planner's CLI surface: `--merge-policy` takes a
        // value (or "true" alone, which MergePolicyKind::parse maps to the
        // cost planner), `--auto-tune` is a boolean switch, and
        // `--merge-threshold` is a plain number
        let a = parse("experiment --merge-policy cost --merge-threshold 0.25 --auto-tune");
        assert_eq!(a.flag("merge-policy"), Some("cost"));
        assert_eq!(a.f64_or("merge-threshold", 0.0).unwrap(), 0.25);
        assert!(a.has("auto-tune"));
        let b = parse("experiment --merge-policy observation-count");
        assert_eq!(b.flag("merge-policy"), Some("observation-count"));
        let c = parse("experiment --merge-policy");
        assert_eq!(c.flag("merge-policy"), Some("true"));
    }
}
