//! Mini property-testing harness (no `proptest` offline).
//!
//! Runs a property over many seeded random cases; on failure it retries the
//! failing case with progressively "smaller" generator budgets
//! (shrinking-lite) and reports the seed so the case can be replayed
//! deterministically:
//!
//! ```no_run
//! use provuse::util::prop::check;
//! check("sum is commutative", 256, |g| {
//!     let a = g.int(0, 1000);
//!     let b = g.int(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case value source handed to properties.
pub struct Gen {
    rng: Rng,
    /// scale in (0, 1]: shrink passes re-run failing seeds with smaller scale
    scale: f64,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Gen { rng: Rng::new(seed), scale }
    }

    /// Stand-alone full-scale generator from an explicit seed — for
    /// properties that must move value generation into a `'static` future
    /// (derive the seed from the enclosing case's `Gen` so replays stay
    /// deterministic).
    pub fn replay(seed: u64) -> Self {
        Gen::new(seed, 1.0)
    }

    /// Integer in `[lo, hi]` (inclusive); range shrinks toward `lo`.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.scale).max(0.0) as u64 + 1;
        lo + self.rng.below(span) as i64
    }

    /// Usize in `[lo, hi]` (inclusive).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let hi = lo + (hi - lo) * self.scale;
        self.rng.range_f64(lo, hi.max(lo + f64::MIN_POSITIVE))
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.rng.below(items.len() as u64) as usize]
    }

    /// Pick an index with probability proportional to `weights[i]` — the
    /// op-mix selector for interleaving properties.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        assert!(!weights.is_empty());
        let total: f64 = weights.iter().filter(|w| **w > 0.0).sum();
        if total <= 0.0 {
            return 0;
        }
        let mut x = self.rng.range_f64(0.0, total);
        for (i, w) in weights.iter().enumerate() {
            if *w <= 0.0 {
                continue;
            }
            if x < *w {
                return i;
            }
            x -= *w;
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle (deterministic per seed).
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.rng.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Vector of values from a per-element closure; length in `[0, max_len]`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(0, max_len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Lowercase identifier of length `[1, max_len]`.
    pub fn ident(&mut self, max_len: usize) -> String {
        let n = self.usize(1, max_len.max(1));
        (0..n)
            .map(|_| (b'a' + self.rng.below(26) as u8) as char)
            .collect()
    }

    /// Raw access to the underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` seeded cases; panics with the failing seed.
/// Honors `PROP_SEED` (replay one case), `PROP_CASES` (case count), and
/// `PROP_SALT` (entropy mixed into every case seed, so scheduled CI runs
/// explore *new* cases instead of replaying the same deterministic set;
/// a reported `PROP_SEED` still replays exactly regardless of salt).
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    if let Ok(seed) = std::env::var("PROP_SEED") {
        let seed: u64 = seed.parse().expect("PROP_SEED must be a u64");
        let mut g = Gen::new(seed, 1.0);
        prop(&mut g);
        return;
    }
    let cases = std::env::var("PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cases);
    let salt: u64 = std::env::var("PROP_SALT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let base = fnv1a(name.as_bytes()) ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        });
        if let Err(payload) = result {
            // shrinking-lite: replay the same seed at smaller scales and
            // report the smallest scale that still fails.
            let mut failing_scale = 1.0;
            for scale in [0.5, 0.25, 0.1, 0.05] {
                let shrunk = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, scale);
                    prop(&mut g);
                });
                if shrunk.is_err() {
                    failing_scale = scale;
                }
            }
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed (case {i}, PROP_SEED={seed}, \
                 min failing scale {failing_scale}): {msg}"
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 64, |g| {
            let a = g.int(0, 100);
            let b = g.int(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails` failed")]
    fn failing_property_reports_seed() {
        check("always fails", 8, |g| {
            let v = g.int(0, 10);
            assert!(v > 100, "v={v}");
        });
    }

    #[test]
    fn weighted_respects_zero_and_dominant_weights() {
        check("weighted picks", 64, |g| {
            // a zero-weight arm is never picked
            for _ in 0..50 {
                let i = g.weighted(&[1.0, 0.0, 3.0]);
                assert_ne!(i, 1);
                assert!(i < 3);
            }
            // a single positive arm is always picked
            assert_eq!(g.weighted(&[0.0, 5.0, 0.0]), 1);
        });
    }

    #[test]
    fn shuffle_is_a_permutation() {
        check("shuffle permutes", 64, |g| {
            let mut v: Vec<i64> = (0..20).collect();
            g.shuffle(&mut v);
            let mut sorted = v.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..20).collect::<Vec<i64>>());
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("ranges", 128, |g| {
            let v = g.int(-5, 5);
            assert!((-5..=5).contains(&v));
            let u = g.usize(2, 4);
            assert!((2..=4).contains(&u));
            let f = g.f64(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
            let s = g.ident(8);
            assert!(!s.is_empty() && s.len() <= 8);
        });
    }
}
