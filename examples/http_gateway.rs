//! Real-time serving demo: start the HTTP front end on a real TCP port
//! (real clock, live PJRT compute), fire requests at it from client
//! threads, and watch fusion kick in while the server is under load.
//!
//! Latencies are scaled to 10% of the paper calibration so the demo
//! finishes in ~20 s of wall time; relative improvements are unchanged.
//!
//! ```bash
//! make artifacts && cargo run --release --example http_gateway
//! ```

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use provuse::apps;
use provuse::config::{ComputeMode, PlatformConfig};

const PORT: u16 = 18080;
const SCALE: f64 = 0.1;
const REQUESTS: usize = 120;

fn http(method: &str, path: &str, body: &str) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(("127.0.0.1", PORT))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let code: u16 = status_line.split_whitespace().nth(1).unwrap_or("0").parse().unwrap_or(0);
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        if line.trim().is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((code, String::from_utf8_lossy(&body).into_owned()))
}

fn wait_for_server() {
    for _ in 0..600 {
        if http("GET", "/healthz", "").map(|(c, _)| c == 200).unwrap_or(false) {
            return;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    panic!("server did not come up on port {PORT}");
}

fn main() {
    // server thread: real-clock executor + TCP front end + live PJRT
    let server = std::thread::spawn(|| {
        let config = PlatformConfig::tiny()
            .with_compute(ComputeMode::Live)
            .scale_latency(SCALE);
        provuse::httpfront::serve(apps::iot(), config, PORT, None).expect("serve failed");
    });
    wait_for_server();
    println!("server is up; firing {REQUESTS} requests...\n");

    let mut latencies = Vec::new();
    let t_start = Instant::now();
    for i in 0..REQUESTS {
        let t0 = Instant::now();
        let (code, _body) = http("POST", "/invoke", "").expect("request failed");
        assert_eq!(code, 200, "request {i} failed");
        latencies.push(t0.elapsed().as_secs_f64() * 1e3);
        if i % 20 == 19 {
            let recent: f64 =
                latencies[latencies.len() - 20..].iter().sum::<f64>() / 20.0;
            let (_, metrics) = http("GET", "/metrics", "").unwrap();
            let merges = metrics
                .split("\"merges\":")
                .nth(1)
                .and_then(|s| s.split(&[',', '}']).next())
                .unwrap_or("?")
                .to_string();
            println!(
                "  [{:5.1}s] req {:>3}: mean latency (last 20) = {:6.1} ms, merges so far: {}",
                t_start.elapsed().as_secs_f64(),
                i + 1,
                recent,
                merges
            );
        }
    }

    let (_, metrics) = http("GET", "/metrics", "").unwrap();
    let (_, routes) = http("GET", "/routes", "").unwrap();
    println!("\nfinal /metrics: {metrics}");
    println!("final /routes:  {routes}");

    let first: f64 = latencies[..20].iter().sum::<f64>() / 20.0;
    let last: f64 = latencies[latencies.len() - 20..].iter().sum::<f64>() / 20.0;
    println!(
        "\nmean latency first 20 requests: {first:.1} ms -> last 20: {last:.1} ms ({:.1}% lower)",
        (first - last) / first * 100.0
    );

    let (code, _) = http("POST", "/shutdown", "").unwrap();
    assert_eq!(code, 200);
    server.join().unwrap();
    println!("server shut down cleanly");
}
