//! End-to-end driver (DESIGN.md §Deliverables): the full IOT sensor
//! pipeline served on both platform flavors with **live PJRT compute** —
//! every function invocation executes its AOT-compiled JAX/Pallas body
//! through the XLA runtime — comparing vanilla vs fusion deployments and
//! verifying that fusion preserves response bytes exactly.
//!
//! ```bash
//! make artifacts && cargo run --release --example iot_pipeline
//! ```
//!
//! The run recorded in EXPERIMENTS.md §End-to-end used the defaults below.

use std::rc::Rc;

use provuse::apps;
use provuse::config::{ComputeMode, PlatformConfig, PlatformKind, WorkloadConfig};
use provuse::exec::{self, Executor, Mode};
use provuse::platform::Platform;
use provuse::workload::{self, request_payload};

const REQUESTS: u64 = 1_000;
const RATE_RPS: f64 = 10.0;

fn run_cell(kind: PlatformKind, fusion: bool) -> provuse::Result<(f64, f64, Vec<f32>)> {
    Executor::new(Mode::Virtual).block_on(async move {
        let mut config = PlatformConfig::of_kind(kind).with_compute(ComputeMode::Live);
        if !fusion {
            config = config.vanilla();
        }
        let platform = Platform::deploy(apps::iot(), config).await?;
        let wl = WorkloadConfig {
            requests: REQUESTS,
            rate_rps: RATE_RPS,
            seed: 42,
            timeout_ms: 60_000.0,
        };
        let report = workload::run(Rc::clone(&platform), wl).await?;
        exec::sleep_ms(5_000.0).await;
        assert_eq!(report.failed, 0, "no request may fail during merging");

        // one reference invocation for the bit-equality check
        let probe = platform
            .invoke(request_payload(123, 0, platform.payload_len()))
            .await?;
        let ram = platform.metrics.ram_mean_mb_after(0.0);
        platform.shutdown();
        println!(
            "  {}/{}: {}",
            kind.name(),
            if fusion { "fusion " } else { "vanilla" },
            report.summary()
        );
        println!(
            "      RAM mean {:.0} MiB, merges {}, live compute on PJRT ({} invocations)",
            ram,
            platform.metrics.merges().len(),
            platform.metrics.counter("invocations")
        );
        Ok((report.latency.median(), ram, probe))
    })
}

fn main() -> provuse::Result<()> {
    println!(
        "IOT pipeline end-to-end ({} requests @ {} rps, live PJRT compute)\n",
        REQUESTS, RATE_RPS
    );
    let mut rows = Vec::new();
    for kind in [PlatformKind::Tiny, PlatformKind::Kube] {
        let (van_ms, van_ram, van_probe) = run_cell(kind, false)?;
        let (fus_ms, fus_ram, fus_probe) = run_cell(kind, true)?;

        // fusion must not change responses: same math, same bytes
        assert_eq!(
            van_probe, fus_probe,
            "fused deployment changed response bytes on {}",
            kind.name()
        );
        rows.push((kind, van_ms, fus_ms, van_ram, fus_ram));
    }

    println!("\nsummary (medians):");
    println!("| platform | vanilla | fusion | latency cut | RAM cut |");
    println!("|----------|--------:|-------:|------------:|--------:|");
    for (kind, v, f, vr, fr) in rows {
        println!(
            "| {} | {:.0} ms | {:.0} ms | {:.1}% | {:.1}% |",
            kind.name(),
            v,
            f,
            (v - f) / v * 100.0,
            (vr - fr) / vr * 100.0
        );
    }
    println!("\nresponse bit-equality vanilla vs fused: VERIFIED");
    Ok(())
}
