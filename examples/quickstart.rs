//! Quickstart: deploy the TREE app on a tinyFaaS-flavored platform, watch
//! the platform detect synchronous calls and fuse instances at runtime, and
//! compare latency before and after.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::rc::Rc;

use provuse::apps;
use provuse::config::{ComputeMode, PlatformConfig, WorkloadConfig};
use provuse::exec::{self, Executor, Mode};
use provuse::platform::Platform;
use provuse::workload;

fn main() -> provuse::Result<()> {
    let ex = Executor::new(Mode::Virtual); // deterministic virtual time
    ex.block_on(async {
        // 1. deploy: one container instance per function, fusion enabled
        let app = apps::tree();
        println!("deploying `{}` ({} functions)...", app.name, app.len());
        println!("theoretical fusion groups: {:?}\n", app.sync_fusion_groups());
        let config = PlatformConfig::tiny().with_compute(ComputeMode::Replay);
        let platform = Platform::deploy(app, config).await?;
        println!(
            "deployed: {} instances, {} MiB platform RAM\n",
            platform.containers.live_count(),
            platform.containers.total_ram_mb() as u64
        );

        // 2. drive a small workload; the Function Handler observes the
        //    blocking calls and the Merger consolidates instances
        let wl = WorkloadConfig { requests: 400, rate_rps: 10.0, seed: 7, timeout_ms: 60_000.0 };
        let report = workload::run(Rc::clone(&platform), wl).await?;
        exec::sleep_ms(5_000.0).await; // let drains settle
        println!("workload: {}\n", report.summary());

        // 3. what happened while we were serving
        println!("merge events:");
        for m in platform.metrics.merges() {
            println!(
                "  t={:>6.1}s  [{}] (pipeline took {:.1}s)",
                m.t_ms / 1e3,
                m.functions.join(" + "),
                m.duration_ms / 1e3
            );
        }
        let pre = platform.metrics.latency_quantiles_window(0.0, 5_000.0);
        let last_merge = platform
            .metrics
            .merges()
            .iter()
            .map(|m| m.t_ms)
            .fold(0.0f64, f64::max);
        let post = platform.metrics.latency_quantiles_window(last_merge, f64::INFINITY);
        println!(
            "\nmedian latency: {:.0} ms (first 5s, pre-merge) -> {:.0} ms (post-merge)",
            pre.median(),
            post.median()
        );
        println!(
            "platform RAM:   {:.0} MiB -> {:.0} MiB  ({} -> {} instances)",
            platform.metrics.ram_series().first().map(|s| s.total_mb).unwrap_or(0.0),
            platform.containers.total_ram_mb(),
            platform.app.len(),
            platform.containers.live_count()
        );
        println!(
            "inline calls served: {}  (remote sync calls observed: {})",
            platform.metrics.counter("inline_calls"),
            platform.metrics.counter("remote_sync_calls")
        );
        platform.shutdown();
        Ok(())
    })
}
