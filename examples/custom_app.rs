//! Public-API tour: define your own application with the builder, attach
//! AOT compute bodies, set trust domains, tune the fusion policy, and
//! inspect what the platform learned about your call graph.
//!
//! The app models a document-processing service: `ingest` synchronously
//! calls `extract`, which synchronously calls `classify` (same trust
//! domain — fusable), `classify` synchronously calls `audit` in a
//! *different* trust domain (must never fuse), and `ingest` asynchronously
//! hands off to `archive`.
//!
//! ```bash
//! make artifacts && cargo run --release --example custom_app
//! ```

use std::rc::Rc;

use provuse::apps::AppSpec;
use provuse::config::{ComputeMode, PlatformConfig, WorkloadConfig};
use provuse::exec::{self, Executor, Mode};
use provuse::platform::Platform;
use provuse::workload;

fn build_app() -> provuse::Result<AppSpec> {
    AppSpec::builder("docproc")
        .function("ingest")
        .entry()
        .body("parse")
        .busy_ms(40.0)
        .code_mb(15.0)
        .trust_domain("pipeline")
        .sync_call("extract")
        .async_call("archive")
        .done()
        .function("extract")
        .body("analyze_sensor")
        .busy_ms(80.0)
        .trust_domain("pipeline")
        .sync_call("classify")
        .done()
        .function("classify")
        .body("aggregate")
        .busy_ms(60.0)
        .trust_domain("pipeline")
        .sync_call("audit")
        .done()
        .function("audit")
        .body("notify")
        .busy_ms(10.0)
        .trust_domain("compliance") // cross-domain: must never fuse
        .done()
        .function("archive")
        .body("persist")
        .busy_ms(50.0)
        .trust_domain("pipeline")
        .done()
        .build()
}

fn main() -> provuse::Result<()> {
    let app = build_app()?;
    println!("app `{}`:\n{}", app.name, app.to_dot());
    println!("fusion groups the platform should converge to: {:?}\n", app.sync_fusion_groups());

    Executor::new(Mode::Virtual).block_on(async {
        // custom fusion policy: aggressive threshold, capped group size
        let mut config = PlatformConfig::tiny().with_compute(ComputeMode::Replay);
        config.fusion.min_observations = 2;
        config.fusion.max_group_size = 3;
        let platform = Platform::deploy(build_app()?, config).await?;

        let wl = WorkloadConfig { requests: 300, rate_rps: 10.0, seed: 1, timeout_ms: 60_000.0 };
        let report = workload::run(Rc::clone(&platform), wl).await?;
        exec::sleep_ms(5_000.0).await;
        println!("workload: {}\n", report.summary());

        println!("observed call graph (sync edges + counts):");
        for ((caller, callee), count) in platform.observer.observed_graph() {
            println!("  {caller} -> {callee}: {count}");
        }

        println!("\nfinal routing:");
        for (function, inst) in platform.gateway.snapshot() {
            println!(
                "  {function:<10} -> {} hosting {:?}",
                inst.id(),
                inst.functions().iter().map(|(f, _)| f.as_str()).collect::<Vec<_>>()
            );
        }

        // invariants this example demonstrates
        let audit_inst = platform.gateway.resolve("audit")?;
        assert_eq!(
            audit_inst.functions().len(),
            1,
            "cross-trust-domain function must stay isolated"
        );
        let ingest_inst = platform.gateway.resolve("ingest")?;
        assert!(
            ingest_inst.functions().len() <= 3,
            "max_group_size=3 must cap fused instances"
        );
        println!("\ninvariants held: audit stayed isolated, group size capped at 3");
        platform.shutdown();
        Ok(())
    })
}
